//! TCP backend for the nomad ring: length-prefixed [`super::wire`]
//! frames over sockets, the `serve-worker` session host, and the
//! coordinator-side remote slot.
//!
//! # Topology
//!
//! The coordinator owns one TCP connection per remote slot and relays
//! through it, so remote workers are topology-blind:
//!
//! ```text
//! coordinator ──(Init/Ring)──▶ serve-worker
//! coordinator ◀─(Forward/Reply/Err)── serve-worker
//! ```
//!
//! Locally, a remote slot is indistinguishable from a thread: it occupies
//! a `Sender<Msg>` in the ring like every other worker.  A writer thread
//! drains that channel onto the socket; a reader thread dispatches
//! incoming `Forward` frames to the successor slot's sender and `Reply`
//! frames to the coordinator's reply channel.  Either thread records a
//! ring fault on socket failure, which the runtime's health check turns
//! into a descriptive error instead of a deadlock.
//!
//! # Framing
//!
//! Every frame is `u32 LE body length | body` with the body produced by
//! [`encode_frame`].  Bodies above [`MAX_FRAME`] are rejected before
//! allocation, so a garbage length cannot OOM the process.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::corpus::CorpusSlice;
use crate::lda::state::Hyper;
use crate::resilience::FaultTransport;
use crate::util::codec::{read_len_prefixed, write_len_prefixed};
use crate::util::rng::Pcg32;

use super::token::{Msg, Reply};
use super::transport::{run_worker, Transport};
use super::wire::{decode_frame, encode_frame, Frame, Init};
use super::worker::WorkerState;

/// Upper bound on one frame body (1 GiB) — far above any real token or
/// state slice, far below an attacker-controlled length field.
pub const MAX_FRAME: usize = 1 << 30;

/// `try_clone` with a house-style error.
fn clone_stream(stream: &TcpStream) -> Result<TcpStream, String> {
    stream.try_clone().map_err(|e| format!("socket clone failed: {e}"))
}

/// How long the coordinator waits for the remote's `InitOk`.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);

/// Write one length-prefixed frame and flush it onto the wire.  Errors
/// (instead of truncating the `u32` prefix) on bodies above
/// [`MAX_FRAME`] — oversized payloads must fail loudly, not desync the
/// stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), String> {
    write_len_prefixed(w, &encode_frame(frame), MAX_FRAME)
}

/// Read one length-prefixed frame.  Errors on EOF, short reads, a length
/// above [`MAX_FRAME`], and every [`decode_frame`] failure.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, String> {
    decode_frame(&read_len_prefixed(r, MAX_FRAME)?)
}

/// Worker-side [`Transport`] over one coordinator connection.
pub struct TcpTransport {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl TcpTransport {
    pub fn new(reader: BufReader<TcpStream>, writer: BufWriter<TcpStream>) -> Self {
        TcpTransport { reader, writer }
    }
}

impl Transport for TcpTransport {
    fn recv(&mut self) -> Result<Msg, String> {
        match read_frame(&mut self.reader)? {
            Frame::Ring(msg) => Ok(msg),
            Frame::Err(e) => Err(format!("coordinator reported: {e}")),
            other => Err(format!("expected a ring frame, got {other:?}")),
        }
    }

    fn send_next(&mut self, msg: Msg) -> Result<(), String> {
        write_frame(&mut self.writer, &Frame::Forward(msg))
    }

    fn reply(&mut self, reply: Reply) -> Result<(), String> {
        write_frame(&mut self.writer, &Frame::Reply(reply))
    }
}

// ----------------------------------------------------------- serve side

/// `serve-worker` options.
#[derive(Default)]
pub struct ServeOpts {
    /// serve a single coordinator session, then return
    pub once: bool,
    /// suppress per-connection logging
    pub quiet: bool,
    /// fault injection (`--fail-after-epochs N`): kill the process on the
    /// first word token after N completed epochs — a deterministic
    /// `kill -9` for the recovery tests
    pub fail_after_epochs: Option<u32>,
}

/// Host ring workers on `listener`: accept a coordinator connection,
/// run the [`Init`] handshake, then loop the worker until `Stop` or
/// disconnect.
///
/// Without `once`, each session runs on its own thread and the host
/// returns to accepting *immediately* — a wedged or crashed training run
/// never blocks the next coordinator, and when a session ends (its ring
/// partner dropped, cleanly or not) the named `rebind` line records that
/// the slot is accepting again.  With `once`, the single session runs
/// inline and a failed session is this call's (and the CLI's) error, so
/// exit codes reflect worker-side failures.
pub fn serve(listener: TcpListener, opts: &ServeOpts) -> Result<(), String> {
    loop {
        let (stream, peer) = listener.accept().map_err(|e| format!("accept failed: {e}"))?;
        if !opts.quiet {
            crate::log_event!(Info, "serve-worker", "coordinator connected from {peer}");
        }
        if opts.once {
            match host_session(stream, opts.fail_after_epochs) {
                // a liveness probe is not the single session --once serves
                Ok(None) => continue,
                Ok(Some(slot)) => {
                    if !opts.quiet {
                        crate::log_event!(
                            Info,
                            "serve-worker",
                            { slot = slot },
                            "session done (ring slot {slot})"
                        );
                    }
                    return Ok(());
                }
                Err(e) => {
                    crate::log_event!(Error, "serve-worker", "session error: {e}");
                    return Err(e);
                }
            }
        }
        let quiet = opts.quiet;
        let fail_after = opts.fail_after_epochs;
        std::thread::spawn(move || {
            match host_session(stream, fail_after) {
                // probes answer and hang up; no session ran, nothing to log
                Ok(None) => return,
                Ok(Some(slot)) => {
                    if !quiet {
                        crate::log_event!(
                            Info,
                            "serve-worker",
                            { slot = slot },
                            "session done (ring slot {slot})"
                        );
                    }
                }
                Err(e) => crate::log_event!(Error, "serve-worker", "session error: {e}"),
            }
            if !quiet {
                // "rebind" is a greppable contract (tests + docs) — keep
                // the word in the message verbatim
                crate::log_event!(
                    Info,
                    "serve-worker",
                    "rebind: ring partner gone, accepting a new coordinator"
                );
            }
        });
    }
}

/// One coordinator session: handshake, build the worker, run the ring
/// loop.  Returns the slot id served, or `None` when the connection was
/// only a liveness probe ([`Frame::Ping`], answered before the
/// handshake — no worker is built and no session state is consumed).
fn host_session(
    stream: TcpStream,
    fail_after_epochs: Option<u32>,
) -> Result<Option<usize>, String> {
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    // Init must arrive within the handshake deadline: a peer that
    // connects and goes silent may not park this single-session host
    // forever (the "survives crashed coordinators" property)
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(clone_stream(&stream)?);
    let mut writer = BufWriter::new(stream);
    let init = match read_frame(&mut reader) {
        Ok(Frame::Init(init)) => init,
        Ok(Frame::Ping) => {
            write_frame(&mut writer, &Frame::Pong)?;
            return Ok(None);
        }
        Ok(other) => {
            let e = format!("handshake must start with Init, got {other:?}");
            let _ = write_frame(&mut writer, &Frame::Err(e.clone()));
            return Err(e);
        }
        Err(e) => {
            let _ = write_frame(&mut writer, &Frame::Err(e.clone()));
            return Err(e);
        }
    };
    // ring traffic has no deadline — an idle epoch boundary is normal
    writer.get_ref().set_read_timeout(None).map_err(|e| e.to_string())?;
    let slot = init.worker_id as usize;
    match build_worker(*init) {
        Ok(state) => {
            write_frame(&mut writer, &Frame::InitOk)?;
            let link = TcpTransport::new(reader, writer);
            match fail_after_epochs {
                Some(n) => run_worker(state, FaultTransport::new(link, n))?,
                None => run_worker(state, link)?,
            }
            Ok(Some(slot))
        }
        Err(e) => {
            let e = format!("invalid Init for ring slot {slot}: {e}");
            let _ = write_frame(&mut writer, &Frame::Err(e.clone()));
            Err(e)
        }
    }
}

/// Validate an [`Init`] and build the [`WorkerState`] it describes.  The
/// corpus slice is reconstructed locally (rebased CSR), so the worker
/// indexes docs `0..n` internally while reporting `start_doc`-based ids.
fn build_worker(init: Init) -> Result<WorkerState, String> {
    // a 0-worker ring (or an out-of-ring slot id) would make every token
    // reply after a single hop instead of circulating — reject loudly
    if init.num_workers == 0 {
        return Err("num_workers must be at least 1".into());
    }
    if init.worker_id >= init.num_workers {
        return Err(format!(
            "worker_id {} outside the {}-slot ring",
            init.worker_id, init.num_workers
        ));
    }
    let t = init.t as usize;
    if !(2..=u16::MAX as usize + 1).contains(&t) {
        return Err(format!("topic count {t} out of range"));
    }
    if init.s.len() != t {
        return Err(format!("totals length {} != T {t}", init.s.len()));
    }
    let sub = CorpusSlice::from_parts(
        init.start_doc as usize,
        init.doc_offsets.iter().map(|&o| o as usize).collect(),
        init.tokens,
        init.vocab as usize,
    )?;
    if init.z.len() != sub.num_tokens() {
        return Err(format!(
            "z has {} assignments, corpus slice {} tokens",
            init.z.len(),
            sub.num_tokens()
        ));
    }
    if let Some(&bad) = init.z.iter().find(|&&z| z as usize >= t) {
        return Err(format!("assignment topic {bad} >= T {t}"));
    }
    let hyper = Hyper { t, alpha: init.alpha, beta: init.beta };
    Ok(WorkerState::new(
        init.worker_id as usize,
        init.num_workers as usize,
        &sub,
        hyper,
        init.z,
        init.s,
        Pcg32::from_parts(init.rng_state, init.rng_inc),
    ))
}

// ----------------------------------------------------- coordinator side

/// The ring-side channel ends a remote slot plugs into: its own inbox
/// plus where its forwards and replies should land.
pub struct RingPorts {
    /// ring input for this slot (drained by the writer thread)
    pub inbox: Receiver<Msg>,
    /// successor slot's sender (fed by the reader thread)
    pub next: Sender<Msg>,
    /// the coordinator's reply channel
    pub reply: Sender<Reply>,
}

/// A connected remote slot: its relay threads plus a stream handle the
/// runtime can force-close if a shutdown stalls.
pub struct RemoteHandle {
    pub addr: String,
    pub stream: TcpStream,
    pub reader: Option<JoinHandle<()>>,
    pub writer: Option<JoinHandle<()>>,
}

/// Connect ring slot `slot` to a `serve-worker` at `addr`: run the
/// `Init` handshake, then spawn the writer/reader relay threads.  Socket
/// failures after the handshake are pushed to `faults` (suppressed once
/// `stopping` is set) — the runtime health check's view of this link.
pub fn connect_worker(
    addr: &str,
    slot: usize,
    init: Init,
    ports: RingPorts,
    faults: Arc<Mutex<Vec<String>>>,
    stopping: Arc<AtomicBool>,
) -> Result<RemoteHandle, String> {
    // connect with a deadline: a black-holed address (dropped SYNs) must
    // be a prompt descriptive error, not an OS-default multi-minute hang
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
    let stream = TcpStream::connect_timeout(&sock, HANDSHAKE_TIMEOUT)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(clone_stream(&stream)?);
    let mut writer = BufWriter::new(clone_stream(&stream)?);

    // handshake with a deadline so a wedged host cannot hang construction
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).map_err(|e| e.to_string())?;
    write_frame(&mut writer, &Frame::Init(Box::new(init)))
        .map_err(|e| format!("worker {addr}: {e}"))?;
    match read_frame(&mut reader).map_err(|e| format!("worker {addr} handshake: {e}"))? {
        Frame::InitOk => {}
        Frame::Err(e) => return Err(format!("worker {addr} rejected init: {e}")),
        other => return Err(format!("worker {addr} handshake: unexpected {other:?}")),
    }
    stream.set_read_timeout(None).map_err(|e| e.to_string())?;

    let fault = {
        let addr = addr.to_string();
        move |what: String| {
            if !stopping.load(Ordering::SeqCst) {
                faults.lock().unwrap().push(format!("remote worker {slot} ({addr}): {what}"));
            }
        }
    };

    let writer_handle = {
        let fault = fault.clone();
        let inbox = ports.inbox;
        std::thread::spawn(move || {
            while let Ok(msg) = inbox.recv() {
                if let Err(e) = write_frame(&mut writer, &Frame::Ring(msg)) {
                    fault(format!("send failed: {e}"));
                    return;
                }
            }
            // inbox closed: the runtime dropped its senders (shutdown)
        })
    };
    let reader_handle = {
        let next = ports.next;
        let reply = ports.reply;
        std::thread::spawn(move || loop {
            match read_frame(&mut reader) {
                Ok(Frame::Forward(msg)) => {
                    if next.send(msg).is_err() {
                        // successor gone: the ring is tearing down
                        return;
                    }
                }
                Ok(Frame::Reply(r)) => {
                    if reply.send(r).is_err() {
                        return;
                    }
                }
                Ok(Frame::Err(e)) => {
                    fault(format!("reported an error: {e}"));
                    return;
                }
                Ok(other) => {
                    fault(format!("sent an unexpected frame: {other:?}"));
                    return;
                }
                Err(e) => {
                    fault(format!("disconnected: {e}"));
                    return;
                }
            }
        })
    };
    Ok(RemoteHandle {
        addr: addr.to_string(),
        stream,
        reader: Some(reader_handle),
        writer: Some(writer_handle),
    })
}

impl RemoteHandle {
    /// True while either relay thread is still running.
    pub fn relays_alive(&self) -> bool {
        let alive = |h: &Option<JoinHandle<()>>| h.as_ref().is_some_and(|h| !h.is_finished());
        alive(&self.reader) || alive(&self.writer)
    }

    /// Force the socket closed (unblocks both relay threads).
    pub fn force_close(&self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    /// Join both relay threads (idempotent).
    pub fn join_relays(&mut self) {
        if let Some(h) = self.writer.take() {
            let _ = h.join();
        }
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lda::SparseCounts;
    use crate::nomad::token::WordToken;

    #[test]
    fn frames_roundtrip_through_the_length_prefix_layer() {
        let row = SparseCounts::from_sorted_pairs(vec![(0, 4), (7, 1)]).unwrap();
        let frames = [
            Frame::InitOk,
            Frame::Ring(Msg::SetS(vec![1, 2, 3])),
            Frame::Reply(Reply::WordDone(WordToken::new(9, row))),
            Frame::Err("boom".into()),
        ];
        let mut buf: Vec<u8> = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for f in &frames {
            assert_eq!(&read_frame(&mut r).unwrap(), f);
        }
        // stream fully consumed; the next read is a clean EOF error
        assert!(read_frame(&mut r).unwrap_err().contains("frame read failed"));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&[0; 16]);
        let err = read_frame(&mut &buf[..]).unwrap_err();
        assert!(err.contains("cap"), "unhelpful error: {err}");
    }

    /// A `Ping` must be answered before the `Init` handshake and must not
    /// consume a `--once` host's single session — the supervisor's
    /// recovery probe depends on both.
    #[test]
    fn ping_is_answered_without_consuming_a_session() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let _ = serve(listener, &ServeOpts { once: true, quiet: true, ..Default::default() });
        });
        // two probes in a row: if the first consumed the --once session,
        // the second connect/read would fail
        for _ in 0..2 {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut reader = BufReader::new(clone_stream(&stream).unwrap());
            let mut writer = BufWriter::new(stream);
            write_frame(&mut writer, &Frame::Ping).unwrap();
            assert_eq!(read_frame(&mut reader).unwrap(), Frame::Pong);
        }
    }

    #[test]
    fn build_worker_rejects_inconsistent_inits() {
        let base = Init {
            worker_id: 1,
            num_workers: 2,
            start_doc: 10,
            t: 8,
            alpha: 50.0 / 8.0,
            beta: 0.01,
            vocab: 5,
            doc_offsets: vec![0, 2, 3],
            tokens: vec![0, 4, 1],
            z: vec![0, 7, 3],
            s: vec![1; 8],
            rng_state: 1,
            rng_inc: 3,
        };
        // the base init is fine and reports global doc ids
        let state = build_worker(base.clone()).unwrap();
        assert_eq!(state.start_doc, 10);
        assert_eq!(state.id, 1);

        let mut bad_t = base.clone();
        bad_t.t = 1;
        assert!(build_worker(bad_t).unwrap_err().contains("topic count"));
        let mut bad_ring = base.clone();
        bad_ring.num_workers = 0;
        assert!(build_worker(bad_ring).unwrap_err().contains("num_workers"));
        let mut bad_slot = base.clone();
        bad_slot.worker_id = 2;
        assert!(build_worker(bad_slot).unwrap_err().contains("outside"));
        let mut bad_s = base.clone();
        bad_s.s = vec![1; 7];
        assert!(build_worker(bad_s).unwrap_err().contains("totals length"));
        let mut bad_z_len = base.clone();
        bad_z_len.z = vec![0, 1];
        assert!(build_worker(bad_z_len).unwrap_err().contains("assignments"));
        let mut bad_z_topic = base.clone();
        bad_z_topic.z = vec![0, 8, 3];
        assert!(build_worker(bad_z_topic).unwrap_err().contains(">= T"));
        let mut bad_word = base.clone();
        bad_word.tokens = vec![0, 5, 1];
        assert!(build_worker(bad_word).is_err());
        let mut bad_offsets = base;
        bad_offsets.doc_offsets = vec![0, 2];
        assert!(build_worker(bad_offsets).is_err());
    }
}
