//! The Nomad parallel framework for LDA (paper §4, Algorithm 4).
//!
//! Decentralized, asynchronous, lock-free CGS built on two kinds of
//! *nomadic tokens*:
//!
//! * **word tokens** `τ_j = (j, w_j)` — carry the *actual* topic-count row
//!   of word j.  Ownership transfer means the row is always up to date and
//!   never shared: no locks, no stale word counts.
//! * **the global token** `τ_s = (0, s)` — carries the topic totals.  Each
//!   worker keeps a local working copy `s_l` and a snapshot `s̄` from the
//!   token's last visit; on arrival it folds its accumulated effort
//!   `s ← s + (s_l − s̄)` and refreshes both copies.  Only these T values
//!   are ever stale, and the staleness is bounded by one circulation.
//!
//! Documents are partitioned per worker ([`crate::corpus::Partition`]), so
//! `d_i` state never moves.  The unit subtask `t_j` is "all occurrences of
//! word j in my documents" — word-by-word F+LDA (decomposition (5)) with
//! the F+tree over `q_t = (n_tw+β)/(s_l+β̄)`.
//!
//! Two execution engines share [`worker::WorkerState`]:
//! * [`runtime`] — real `std::thread` workers + channels (the deployable
//!   artifact; exercised with small p on this 1-core session);
//! * [`crate::simnet`] — virtual-time discrete-event execution with a
//!   calibrated cost model (reproduces the paper's 20-core and 32-node
//!   figures; see DESIGN.md §Hardware-Adaptation).
//!
//! # Crossing the process boundary
//!
//! The ring's communication is abstracted behind [`transport::Transport`]
//! (receive / forward-to-successor / reply-to-coordinator), with two
//! backends sharing one worker loop ([`transport::run_worker`]):
//! in-process mpsc channels, and a length-prefixed TCP session ([`net`])
//! speaking the compact binary format of [`wire`].  `fnomad-lda
//! serve-worker --listen host:port` hosts a [`worker::WorkerState`] in
//! another process (or machine), and `train --runtime nomad --remote
//! host:port,...` splices those hosts into the ring after the local
//! threads.  The epoch protocol, the exact-fold invariant, and every
//! per-slot RNG stream are identical across backends — the multi-machine
//! regime of §4 is the same algorithm over a different wire.

pub mod net;
pub mod runtime;
pub mod token;
pub mod transport;
pub mod wire;
pub mod worker;

pub use runtime::{NomadConfig, NomadRuntime};
pub use token::{GlobalToken, WordToken};

#[cfg(test)]
mod tests {
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;
    use crate::lda::{log_likelihood, LdaState};
    use crate::util::rng::Pcg32;

    use super::runtime::{NomadConfig, NomadRuntime};

    /// End-to-end: the threaded nomad runtime improves LL and its final
    /// gathered state is count-consistent with the corpus.
    #[test]
    fn threaded_nomad_trains_tiny_corpus() {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(16);
        let cfg = NomadConfig { workers: 3, seed: 99, ..Default::default() };
        let mut rt = NomadRuntime::new(&corpus, hyper, cfg);
        let ll0 = {
            let state = rt.gather_state(&corpus);
            state.check_consistency(&corpus).unwrap();
            log_likelihood(&state)
        };
        rt.run_epochs(5);
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        let ll5 = log_likelihood(&state);
        assert!(ll5 > ll0, "nomad LL did not improve: {ll0} -> {ll5}");
        rt.shutdown();
    }

    /// Different worker counts converge to comparable quality (the
    /// correctness half of Fig. 5c; the *speed* half runs in simnet).
    #[test]
    fn worker_count_does_not_change_quality() {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let mut lls = Vec::new();
        for workers in [1usize, 2, 4] {
            let cfg = NomadConfig { workers, seed: 5, ..Default::default() };
            let mut rt = NomadRuntime::new(&corpus, hyper, cfg);
            rt.run_epochs(12);
            let state = rt.gather_state(&corpus);
            state.check_consistency(&corpus).unwrap();
            lls.push(log_likelihood(&state));
            rt.shutdown();
        }
        let serial_ref = {
            let mut rng = Pcg32::seeded(5);
            let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
            let mut s = crate::lda::FLdaWord::new(&state, &corpus);
            for _ in 0..12 {
                crate::lda::Sweep::sweep(&mut s, &mut state, &corpus, &mut rng);
            }
            log_likelihood(&state)
        };
        for (i, &ll) in lls.iter().enumerate() {
            assert!(
                (ll - serial_ref).abs() / serial_ref.abs() < 0.03,
                "workers={} ll={ll} vs serial {serial_ref}",
                [1, 2, 4][i]
            );
        }
    }
}
