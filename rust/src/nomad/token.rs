//! Nomadic tokens (§4.1): the only objects that ever cross worker
//! boundaries.  A word token owns its count row — there is no other copy
//! anywhere in the system, which is what makes the scheme lock-free *and*
//! fresh.  When a boundary is a process boundary, [`Msg`] and [`Reply`]
//! travel as the compact binary frames of [`super::wire`].

use crate::lda::SparseCounts;

/// `τ_j = (j, w_j)`: word id + the authoritative topic-count row.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WordToken {
    pub word: u32,
    /// n_{·,*,w}: the word's topic counts (owned; always current)
    pub counts: SparseCounts,
    /// workers visited in the current epoch
    pub hops: u32,
}

impl WordToken {
    pub fn new(word: u32, counts: SparseCounts) -> Self {
        WordToken { word, counts, hops: 0 }
    }
}

/// `τ_s = (0, s)`: the circulating global topic totals.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GlobalToken {
    pub s: Vec<i64>,
    pub hops: u32,
}

impl GlobalToken {
    pub fn new(s: Vec<i64>) -> Self {
        GlobalToken { s, hops: 0 }
    }
}

/// Messages a worker can receive.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Msg {
    Word(WordToken),
    Global(GlobalToken),
    /// epoch-boundary: fold `s_l − s̄` and reply with the delta
    SyncS,
    /// epoch-boundary: adopt the reduced global totals
    SetS(Vec<i64>),
    /// request a snapshot of the worker's doc-side state
    ReportDocs,
    Stop,
}

/// Replies a worker sends to the coordinator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reply {
    /// a word token that completed its circulation this epoch
    WordDone(WordToken),
    /// the global token absorbed at epoch end
    GlobalDone(GlobalToken),
    /// SyncS answer: accumulated local effort since the last snapshot.
    /// `sample_ns`/`wait_ns` split the epoch's wall time at the worker's
    /// transport boundary — nanoseconds spent processing tokens vs parked
    /// in `recv()` — measured by [`super::transport::run_worker`] (never
    /// inside the sampler) and reset at each `SyncS`.
    SDelta {
        worker: usize,
        delta: Vec<i64>,
        tokens_processed: u64,
        sample_ns: u64,
        wait_ns: u64,
    },
    /// ReportDocs answer: sparse doc-topic rows plus the flat CSR
    /// assignment payload for the worker's contiguous doc range
    Docs { worker: usize, start_doc: usize, ntd: Vec<SparseCounts>, z: Vec<u16> },
}
