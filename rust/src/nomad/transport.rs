//! The worker side of the ring, abstracted over its communication
//! substrate.
//!
//! A ring worker does exactly three things with the outside world:
//! receive the next [`Msg`], forward a token to its successor slot, and
//! reply to the coordinator.  [`Transport`] captures those three verbs;
//! [`run_worker`] is the one ring loop shared by every backend:
//!
//! * [`ChannelTransport`] — in-process `std::sync::mpsc` (threaded mode);
//! * [`super::net::TcpTransport`] — a length-prefixed TCP session hosted
//!   by `fnomad-lda serve-worker` (cross-process mode).  Its "forward"
//!   goes back over the coordinator connection tagged
//!   [`super::wire::Frame::Forward`]; the coordinator relays it to the
//!   successor, so remote workers never need to know the ring topology;
//! * [`crate::resilience::FaultTransport`] — a wrapper over either that
//!   kills the process after N epochs (`serve-worker --fail-after-epochs`),
//!   the deterministic `kill -9` behind the recovery tests.
//!
//! Every verb is fallible: a closed channel or dropped socket returns
//! `Err` and [`run_worker`] exits, which is what lets the coordinator's
//! health check distinguish a broken ring from a quiet one instead of
//! deadlocking (see `runtime`).

use std::sync::mpsc::{Receiver, Sender};

use super::token::{Msg, Reply};
use super::worker::WorkerState;

/// A worker's three-verb connection to the ring.
pub trait Transport {
    /// Block for the next ring input.  `Err` means the ring is gone.
    fn recv(&mut self) -> Result<Msg, String>;

    /// Pass a token to the successor slot.
    fn send_next(&mut self, msg: Msg) -> Result<(), String>;

    /// Answer the coordinator.
    fn reply(&mut self, reply: Reply) -> Result<(), String>;
}

/// In-process backend: the ring is mpsc channels, the successor is a
/// clone of its input sender.
pub struct ChannelTransport {
    pub rx: Receiver<Msg>,
    pub next: Sender<Msg>,
    pub reply: Sender<Reply>,
}

impl Transport for ChannelTransport {
    fn recv(&mut self) -> Result<Msg, String> {
        self.rx.recv().map_err(|_| "ring input channel closed".into())
    }

    fn send_next(&mut self, msg: Msg) -> Result<(), String> {
        self.next.send(msg).map_err(|_| "successor channel closed".into())
    }

    fn reply(&mut self, reply: Reply) -> Result<(), String> {
        self.reply.send(reply).map_err(|_| "coordinator reply channel closed".into())
    }
}

/// The ring loop every worker runs, local thread or remote process
/// (Algorithm 4 dispatch; the epoch protocol lives in `runtime`).
///
/// Returns `Ok(())` on a clean [`Msg::Stop`], `Err` when the transport
/// breaks mid-session — callers decide whether that is a fault (the
/// coordinator's health check) or routine teardown.
///
/// This loop is also where the per-slot telemetry clocks live: time
/// parked in `recv()` accumulates as wait, time spent processing word/
/// global tokens accumulates as sample, and both ride back to the
/// coordinator in the epoch-end [`Reply::SDelta`].  The clocks wrap the
/// transport verbs — sampler scope stays wall-clock-free (`xtask
/// lint-invariants`), and timing never changes what gets computed, so
/// fixed-seed runs stay bit-identical.
pub fn run_worker<T: Transport>(mut state: WorkerState, mut link: T) -> Result<(), String> {
    let p = state.num_workers as u32;
    let mut sample_ns = 0u64;
    let mut wait_ns = 0u64;
    loop {
        let t_wait = std::time::Instant::now();
        let msg = link.recv()?;
        wait_ns += t_wait.elapsed().as_nanos() as u64;
        match msg {
            Msg::Word(mut tok) => {
                let t0 = std::time::Instant::now();
                state.process_word_token(&mut tok);
                sample_ns += t0.elapsed().as_nanos() as u64;
                tok.hops += 1;
                if tok.hops >= p {
                    link.reply(Reply::WordDone(tok))?;
                } else {
                    link.send_next(Msg::Word(tok))?;
                }
            }
            Msg::Global(mut tok) => {
                let t0 = std::time::Instant::now();
                state.process_global_token(&mut tok);
                sample_ns += t0.elapsed().as_nanos() as u64;
                tok.hops += 1;
                if tok.hops >= p * super::runtime::S_CIRCULATIONS {
                    link.reply(Reply::GlobalDone(tok))?;
                } else {
                    link.send_next(Msg::Global(tok))?;
                }
            }
            Msg::SyncS => {
                let delta = state.take_s_delta();
                link.reply(Reply::SDelta {
                    worker: state.id,
                    delta,
                    tokens_processed: state.processed,
                    sample_ns: std::mem::take(&mut sample_ns),
                    wait_ns: std::mem::take(&mut wait_ns),
                })?;
            }
            Msg::SetS(s) => state.set_s(&s),
            Msg::ReportDocs => {
                // z is already flat — one bulk clone, no per-doc Vecs
                link.reply(Reply::Docs {
                    worker: state.id,
                    start_doc: state.start_doc,
                    ntd: state.ntd.clone(),
                    z: state.z.clone(),
                })?;
            }
            Msg::Stop => return Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::channel;

    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::{Hyper, LdaState, SparseCounts};
    use crate::nomad::token::WordToken;
    use crate::util::rng::Pcg32;

    /// Drive a single-worker ring through one epoch by hand over the
    /// channel transport: every token comes home with hops == 1, SyncS
    /// folds, Stop exits cleanly.
    #[test]
    fn channel_transport_single_worker_epoch() {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(3);
        let init = LdaState::init_random(&corpus, hyper, &mut rng);
        let s: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();
        let slice = corpus.read_range(0, corpus.num_docs());
        let state = WorkerState::new(0, 1, &slice, hyper, init.z.clone(), s, Pcg32::seeded(4));
        let (tx, rx) = channel();
        let (reply_tx, replies) = channel();
        let link = ChannelTransport { rx, next: tx.clone(), reply: reply_tx };
        let handle = std::thread::spawn(move || run_worker(state, link));

        for (w, counts) in init.nwt.iter().enumerate() {
            tx.send(Msg::Word(WordToken::new(w as u32, counts.clone()))).unwrap();
        }
        tx.send(Msg::SyncS).unwrap();
        let mut mass = 0u64;
        for _ in 0..corpus.vocab() {
            match replies.recv().unwrap() {
                Reply::WordDone(tok) => {
                    assert_eq!(tok.hops, 1);
                    mass += tok.counts.total();
                }
                other => panic!("expected WordDone, got {other:?}"),
            }
        }
        assert_eq!(mass as usize, corpus.num_tokens());
        match replies.recv().unwrap() {
            Reply::SDelta { worker, delta, tokens_processed, sample_ns, .. } => {
                assert_eq!(worker, 0);
                assert_eq!(delta.iter().sum::<i64>(), 0, "mass-conserving fold");
                assert_eq!(tokens_processed as usize, corpus.num_tokens());
                assert!(sample_ns > 0, "token processing was timed");
            }
            other => panic!("expected SDelta, got {other:?}"),
        }
        tx.send(Msg::Stop).unwrap();
        handle.join().unwrap().unwrap();
    }

    /// Dropping the ring around a live worker makes `run_worker` return
    /// an error (the signal the coordinator health check rides on), not
    /// hang or panic.
    #[test]
    fn broken_ring_is_an_err_not_a_hang() {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        // worker owns doc 0 with everything assigned topic 0
        let slice = corpus.read_range(0, 1);
        let state = WorkerState::new(
            0,
            // pretend a 2-slot ring so a fresh token gets forwarded
            2,
            &slice,
            hyper,
            vec![0u16; corpus.doc_len(0)],
            vec![corpus.doc_len(0) as i64; 8],
            Pcg32::seeded(9),
        );
        let (tx, rx) = channel();
        let (dead_tx, dead_rx) = channel::<Msg>();
        drop(dead_rx); // successor is already gone
        let (reply_tx, _replies) = channel();
        let link = ChannelTransport { rx, next: dead_tx, reply: reply_tx };
        let handle = std::thread::spawn(move || run_worker(state, link));
        // token counts consistent with the worker's view of word 0
        let occ = corpus.doc(0).iter().filter(|&&w| w == 0).count() as u32;
        let mut counts = SparseCounts::default();
        counts.set_count(0, occ);
        tx.send(Msg::Word(WordToken::new(0, counts))).unwrap();
        let err = handle.join().unwrap().unwrap_err();
        assert!(err.contains("successor"), "unhelpful error: {err}");
    }
}
