//! AD-LDA (Newman, Asuncion, Smyth & Welling, JMLR'09) — the bulk-
//! synchronous baseline of §4.2.
//!
//! Every "machine" sweeps its document partition against a *frozen
//! snapshot* of the word-topic counts taken at the start of the iteration,
//! then all local deltas are reduced into the global state at a barrier.
//! Staleness is a whole iteration (vs. Yahoo!LDA's push period and Nomad's
//! one-s-circulation), which slows per-iteration convergence as p grows —
//! the effect AD-LDA's authors quantify and the nomad design removes.
//!
//! Execution here is sequential over workers (the semantics of the
//! algorithm are unchanged — workers only interact at the barrier); the
//! discrete-event simulator charges the parallel wall-clock including the
//! last-reducer penalty.

use crate::corpus::{Corpus, Partition};
use crate::lda::state::{checked_totals, Hyper, LdaState, SparseCounts};
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

/// AD-LDA configuration.
#[derive(Clone, Debug)]
pub struct AdLdaConfig {
    pub workers: usize,
    pub seed: u64,
}

impl Default for AdLdaConfig {
    fn default() -> Self {
        AdLdaConfig { workers: 2, seed: 0 }
    }
}

/// Bulk-synchronous LDA trainer.
pub struct AdLda {
    pub state: LdaState,
    partition: Partition,
    rngs: Vec<Pcg32>,
    tree: FTree,
    r: SparseCumSum,
    /// per-iteration max worker token count (last-reducer telemetry)
    pub max_worker_tokens: usize,
}

impl AdLda {
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: AdLdaConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0xAD1DA);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, state, cfg)
    }

    /// Build from explicit initial assignments (the resume path).
    pub fn from_state(corpus: &Corpus, state: LdaState, cfg: AdLdaConfig) -> Self {
        // offsets equality (not just doc count): under the flat layout a
        // doc-length mismatch would misindex z silently
        assert_eq!(state.doc_offsets.as_slice(), corpus.offsets(), "init state / corpus mismatch");
        let hyper = state.hyper;
        // worker streams derive from a different stream id than the init
        // draws (0xAD1DA in `new`), so sampling never replays them
        let mut rng = Pcg32::new(cfg.seed, 0xAD1DB);
        let partition = Partition::by_tokens(corpus, cfg.workers);
        let rngs = (0..cfg.workers).map(|l| rng.split(l as u64 + 1)).collect();
        let max_worker_tokens =
            partition.loads(corpus).into_iter().max().unwrap_or(0);
        let t = hyper.t;
        AdLda {
            state,
            partition,
            rngs,
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
            max_worker_tokens,
        }
    }

    /// One bulk-synchronous iteration: all workers sweep against the same
    /// frozen word/topic snapshot; deltas merge at the barrier.
    pub fn iterate(&mut self, corpus: &Corpus) {
        let h = self.state.hyper;
        let bb = h.betabar(self.state.vocab);
        // freeze the word-side state
        let nwt_snap: Vec<SparseCounts> = self.state.nwt.clone();
        let nt_snap: Vec<u32> = self.state.nt.clone();

        // global deltas accumulated across workers
        let mut nwt_delta: Vec<Vec<(u16, i32)>> = vec![Vec::new(); self.state.vocab];
        let mut nt_delta = vec![0i64; h.t];

        for l in 0..self.partition.num_workers() {
            let (start, end) = self.partition.ranges[l];
            // worker-local copies of the frozen snapshot
            let mut nwt_local = nwt_snap.clone();
            let mut nt_local: Vec<i64> = nt_snap.iter().map(|&v| v as i64).collect();
            let mut rng = self.rngs[l].clone();

            let base: Vec<f64> = nt_local
                .iter()
                .map(|&n| h.alpha / (n.max(0) as f64 + bb))
                .collect();
            self.tree.refill(&base);

            let mut sweep = corpus.docs_in(start..end);
            while let Some((doc, toks)) = sweep.next_doc() {
                let support: Vec<u16> = self.state.ntd[doc].iter().map(|(t, _)| t).collect();
                for &t in &support {
                    let q = (self.state.ntd[doc].get(t) as f64 + h.alpha)
                        / (nt_local[t as usize].max(0) as f64 + bb);
                    self.tree.set(t as usize, q);
                }
                let row = self.state.doc_offsets[doc];
                for (pos, &wtok) in toks.iter().enumerate() {
                    let word = wtok as usize;
                    let old = self.state.z[row + pos];
                    self.state.ntd[doc].dec(old);
                    if nwt_local[word].get(old) > 0 {
                        nwt_local[word].dec(old);
                    }
                    nt_local[old as usize] -= 1;
                    record(&mut nwt_delta[word], old, -1);
                    nt_delta[old as usize] -= 1;
                    let q = (self.state.ntd[doc].get(old) as f64 + h.alpha)
                        / (nt_local[old as usize].max(0) as f64 + bb);
                    self.tree.set(old as usize, q);

                    self.r.clear();
                    for (t, c) in nwt_local[word].iter() {
                        self.r.push(t as u32, c as f64 * self.tree.leaf(t as usize));
                    }
                    let r_total = self.r.total();
                    let u = rng.uniform(h.beta * self.tree.total() + r_total);
                    let new = if u < r_total {
                        self.r.sample(u) as u16
                    } else {
                        self.tree.sample((u - r_total) / h.beta) as u16
                    };

                    self.state.ntd[doc].inc(new);
                    nwt_local[word].inc(new);
                    nt_local[new as usize] += 1;
                    record(&mut nwt_delta[word], new, 1);
                    nt_delta[new as usize] += 1;
                    let q = (self.state.ntd[doc].get(new) as f64 + h.alpha)
                        / (nt_local[new as usize].max(0) as f64 + bb);
                    self.tree.set(new as usize, q);
                    self.state.z[row + pos] = new;
                }
                let support: Vec<u16> = self.state.ntd[doc].iter().map(|(t, _)| t).collect();
                for &t in &support {
                    self.tree.set(
                        t as usize,
                        h.alpha / (nt_local[t as usize].max(0) as f64 + bb),
                    );
                }
            }
            self.rngs[l] = rng;
        }

        // barrier: reduce deltas into the authoritative state
        for (word, deltas) in nwt_delta.into_iter().enumerate() {
            for (t, d) in deltas {
                match d.cmp(&0) {
                    std::cmp::Ordering::Greater => {
                        for _ in 0..d {
                            self.state.nwt[word].inc(t);
                        }
                    }
                    std::cmp::Ordering::Less => {
                        for _ in 0..(-d) {
                            self.state.nwt[word].dec(t);
                        }
                    }
                    std::cmp::Ordering::Equal => {}
                }
            }
        }
        // a negative (or overflowed) total after the barrier reduce is
        // lost-delta corruption; checked_totals surfaces it instead of
        // clamping it away
        let reduced: Vec<i64> = self
            .state
            .nt
            .iter()
            .zip(nt_delta)
            .map(|(&acc, d)| acc as i64 + d)
            .collect();
        self.state.nt = checked_totals(&reduced);
    }
}

fn record(deltas: &mut Vec<(u16, i32)>, topic: u16, d: i32) {
    match deltas.binary_search_by_key(&topic, |&(t, _)| t) {
        Ok(i) => {
            deltas[i].1 += d;
            if deltas[i].1 == 0 {
                deltas.remove(i);
            }
        }
        Err(i) => deltas.insert(i, (topic, d)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::log_likelihood;

    #[test]
    fn adlda_converges_and_stays_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut trainer = AdLda::new(&corpus, Hyper::paper_default(8), AdLdaConfig {
            workers: 3,
            seed: 1,
        });
        let ll0 = log_likelihood(&trainer.state);
        for _ in 0..8 {
            trainer.iterate(&corpus);
        }
        trainer.state.check_consistency(&corpus).unwrap();
        assert!(log_likelihood(&trainer.state) > ll0);
    }

    #[test]
    fn single_worker_adlda_is_plain_flda_doc_semantics() {
        // with p = 1 there is no staleness: behaves like serial F+LDA(doc)
        let corpus = preset("tiny").unwrap();
        let mut trainer = AdLda::new(&corpus, Hyper::paper_default(8), AdLdaConfig {
            workers: 1,
            seed: 2,
        });
        for _ in 0..5 {
            trainer.iterate(&corpus);
        }
        trainer.state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn last_reducer_telemetry() {
        let corpus = preset("tiny").unwrap();
        let trainer = AdLda::new(&corpus, Hyper::paper_default(8), AdLdaConfig {
            workers: 4,
            seed: 3,
        });
        assert!(trainer.max_worker_tokens >= corpus.num_tokens() / 4);
        assert!(trainer.max_worker_tokens <= corpus.num_tokens());
    }
}
