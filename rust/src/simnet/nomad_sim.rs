//! Nomad LDA under virtual time.
//!
//! Same epoch protocol as [`crate::nomad::runtime`] (tokens hop the ring,
//! `τ_s` circulates, exact fold at the boundary), same
//! [`WorkerState`] math — but workers are simulated entities: each is busy
//! for `CostModel::word_task_ns(...)` of virtual time per subtask, and
//! token transfers cost `ClusterSpec::transfer_ns(...)`.  Ring routing is
//! machine-aware: consecutive worker ids share a machine, so most hops are
//! intra-node and only every 20th hop crosses the network (the same
//! locality the real Nomad layout gives).

use std::collections::VecDeque;

use crate::coordinator::EpochReport;
use crate::corpus::{Corpus, Partition};
use crate::lda::state::{assemble_state, checked_totals, Hyper, LdaState, SparseCounts};
use crate::nomad::token::{GlobalToken, WordToken};
use crate::nomad::worker::WorkerState;
use crate::util::rng::Pcg32;

use super::{ClusterSpec, CostModel, EventQueue};

/// Simulated-run configuration.
#[derive(Clone, Debug)]
pub struct NomadSimConfig {
    pub cluster: ClusterSpec,
    pub cost: CostModel,
    pub seed: u64,
    /// τ_s circulations per epoch
    pub s_circulations: u32,
}

impl NomadSimConfig {
    pub fn new(cluster: ClusterSpec, t: usize) -> Self {
        NomadSimConfig {
            cluster,
            cost: CostModel::default_for(t),
            seed: 0,
            s_circulations: 4,
        }
    }
}

enum Token {
    Word(WordToken),
    Global(GlobalToken),
}

enum Event {
    /// token arrives at worker's inbox
    Deliver(usize, Token),
    /// worker finishes its current token
    Complete(usize),
}

/// The simulated nomad cluster.
pub struct NomadSim {
    workers: Vec<WorkerState>,
    inboxes: Vec<VecDeque<Token>>,
    current: Vec<Option<Token>>,
    cfg: NomadSimConfig,
    hyper: Hyper,
    /// virtual clock (ns)
    now: u64,
    home: Vec<WordToken>,
    s: Vec<i64>,
    num_words: usize,
    pub epochs_run: usize,
    processed_total: u64,
}

impl NomadSim {
    /// Build from a random initial state (see [`Self::from_state`]).
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: NomadSimConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x51AD);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, &state, cfg)
    }

    /// Build from explicit initial assignments (the resume path).
    pub fn from_state(corpus: &Corpus, init: &LdaState, cfg: NomadSimConfig) -> Self {
        let p = cfg.cluster.total_workers();
        assert!(p >= 1);
        // offsets equality (not just doc count) — see NomadRuntime::from_state
        assert_eq!(init.doc_offsets.as_slice(), corpus.offsets(), "init state / corpus mismatch");
        let hyper = init.hyper;
        let partition = Partition::by_tokens(corpus, p);
        // worker streams derive from a different stream id than the init
        // draws (0x51AD in `new`), so sampling never replays them
        let mut seed_rng = Pcg32::new(cfg.seed, 0xAD51);

        let s: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();
        let home: Vec<WordToken> = init
            .nwt
            .iter()
            .cloned()
            .enumerate()
            .map(|(w, counts)| WordToken::new(w as u32, counts))
            .collect();

        let mut workers = Vec::with_capacity(p);
        for l in 0..p {
            let (start, end) = partition.ranges[l];
            let slice = corpus.read_range(start, end);
            workers.push(WorkerState::new(
                l,
                p,
                &slice,
                hyper,
                init.z_range(start, end).to_vec(),
                s.clone(),
                seed_rng.split(l as u64 + 1),
            ));
        }
        let num_words = home.len();
        NomadSim {
            workers,
            inboxes: (0..p).map(|_| VecDeque::new()).collect(),
            current: (0..p).map(|_| None).collect(),
            cfg,
            hyper,
            now: 0,
            home,
            s,
            num_words,
            epochs_run: 0,
            processed_total: 0,
        }
    }

    fn token_bytes(&self, tok: &Token) -> usize {
        match tok {
            // word id + hops + (topic, count) pairs
            Token::Word(w) => 8 + 6 * w.counts.support(),
            Token::Global(_) => 8 * self.hyper.t,
        }
    }

    /// Virtual service time of a token on worker `l`.
    fn service_ns(&self, l: usize, tok: &Token) -> u64 {
        match tok {
            Token::Word(w) => {
                let occ = self.workers[l].occurrence_count(w.word as usize);
                self.cfg.cost.word_task_ns(occ, w.counts.support())
            }
            Token::Global(_) => self.cfg.cost.global_task_ns(self.hyper.t),
        }
    }

    /// Run one epoch of virtual time; returns stats at the boundary.
    pub fn run_epoch(&mut self) -> EpochReport {
        let p = self.workers.len();
        let epoch_start = self.now;
        let mut msgs = 0u64;
        let mut queue: EventQueue<Event> = EventQueue::new();

        // inject word tokens round-robin + the global token at worker 0
        let tokens: Vec<WordToken> = std::mem::take(&mut self.home);
        for (i, mut tok) in tokens.into_iter().enumerate() {
            tok.hops = 0;
            // injection is free: tokens were already resident from the
            // previous epoch; measurement starts at the boundary
            queue.schedule(self.now, Event::Deliver(i % p, Token::Word(tok)));
        }
        queue.schedule(
            self.now,
            Event::Deliver(0, Token::Global(GlobalToken::new(self.s.clone()))),
        );

        let mut words_home: Vec<WordToken> = Vec::with_capacity(self.num_words);
        let mut global_home: Option<GlobalToken> = None;

        while words_home.len() < self.num_words || global_home.is_none() {
            let (t, ev) = queue.pop().expect("simulation starved");
            self.now = t;
            match ev {
                Event::Deliver(l, tok) => {
                    self.inboxes[l].push_back(tok);
                    if self.current[l].is_none() {
                        self.start_next(l, &mut queue);
                    }
                }
                Event::Complete(l) => {
                    let tok = self.current[l].take().expect("complete without token");
                    match tok {
                        Token::Word(mut w) => {
                            w.hops += 1;
                            if w.hops as usize >= p {
                                words_home.push(w);
                            } else {
                                let next = (l + 1) % p;
                                let bytes = self.token_bytes(&Token::Word(w.clone()));
                                let dt = self.cfg.cluster.transfer_ns(bytes, l, next);
                                msgs += 1;
                                queue.schedule(
                                    self.now + dt,
                                    Event::Deliver(next, Token::Word(w)),
                                );
                            }
                        }
                        Token::Global(mut g) => {
                            g.hops += 1;
                            if g.hops >= p as u32 * self.cfg.s_circulations {
                                global_home = Some(g);
                            } else {
                                let next = (l + 1) % p;
                                let dt = self
                                    .cfg
                                    .cluster
                                    .transfer_ns(8 * self.hyper.t, l, next);
                                msgs += 1;
                                queue.schedule(
                                    self.now + dt,
                                    Event::Deliver(next, Token::Global(g)),
                                );
                            }
                        }
                    }
                    if !self.inboxes[l].is_empty() {
                        self.start_next(l, &mut queue);
                    }
                }
            }
        }

        // exact epoch fold (direct access: the sim is single-threaded)
        words_home.sort_by_key(|t| t.word);
        self.home = words_home;
        let mut s = global_home.unwrap().s;
        let mut processed = 0u64;
        for w in &mut self.workers {
            for (acc, d) in s.iter_mut().zip(w.take_s_delta()) {
                *acc += d;
            }
            processed += w.processed;
        }
        for w in &mut self.workers {
            w.set_s(&s);
        }
        self.s = s;
        self.epochs_run += 1;
        let delta = processed - self.processed_total;
        self.processed_total = processed;
        EpochReport {
            processed: delta,
            secs: (self.now - epoch_start) as f64 / 1e9,
            stale_reads: 0,
            msgs,
            ring: None,
        }
    }

    /// Pop the worker's next token, perform the *real* state update, and
    /// schedule its completion after the modeled service time.
    fn start_next(&mut self, l: usize, queue: &mut EventQueue<Event>) {
        let mut tok = self.inboxes[l].pop_front().expect("start with empty inbox");
        let dur = self.service_ns(l, &tok);
        match &mut tok {
            Token::Word(w) => {
                self.workers[l].process_word_token(w);
            }
            Token::Global(g) => {
                self.workers[l].process_global_token(g);
            }
        }
        self.current[l] = Some(tok);
        queue.schedule(self.now + dur, Event::Complete(l));
    }

    /// Virtual seconds elapsed since simulation start.
    pub fn vtime_secs(&self) -> f64 {
        self.now as f64 / 1e9
    }

    /// Assemble the exact global state (epoch boundaries only).
    ///
    /// Panics if the folded global totals contain a negative entry — that
    /// is count-state corruption, not a value to clamp away.
    pub fn gather_state(&mut self, corpus: &Corpus) -> LdaState {
        let parts = self
            .workers
            .iter()
            .map(|w| (w.start_doc, w.ntd.as_slice(), w.z.as_slice()));
        let mut nwt = vec![SparseCounts::default(); corpus.vocab()];
        for tok in &self.home {
            nwt[tok.word as usize] = tok.counts.clone();
        }
        assemble_state(corpus, self.hyper, parts, nwt, checked_totals(&self.s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::log_likelihood;

    fn sim(corpus: &Corpus, workers: usize, seed: u64) -> NomadSim {
        let mut cfg =
            NomadSimConfig::new(ClusterSpec::multicore(workers), 8);
        cfg.seed = seed;
        NomadSim::new(corpus, Hyper::paper_default(8), cfg)
    }

    #[test]
    fn simulated_epoch_is_exact_and_improves_ll() {
        let corpus = preset("tiny").unwrap();
        let mut s = sim(&corpus, 4, 1);
        let ll0 = log_likelihood(&s.gather_state(&corpus));
        let stats = s.run_epoch();
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        assert!(stats.secs > 0.0);
        assert!(stats.msgs > 0);
        let state = s.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        for _ in 0..5 {
            s.run_epoch();
        }
        assert!(log_likelihood(&s.gather_state(&corpus)) > ll0);
    }

    #[test]
    fn more_workers_less_virtual_time() {
        let corpus = preset("tiny").unwrap();
        let t1 = {
            let mut s = sim(&corpus, 1, 2);
            s.run_epoch().secs
        };
        let t8 = {
            let mut s = sim(&corpus, 8, 2);
            s.run_epoch().secs
        };
        assert!(
            t8 * 3.0 < t1,
            "8 workers should be >3x faster in virtual time: t1={t1} t8={t8}"
        );
    }

    #[test]
    #[should_panic(expected = "state corruption")]
    fn gather_state_panics_on_negative_total() {
        let corpus = preset("tiny").unwrap();
        let mut s = sim(&corpus, 2, 5);
        s.run_epoch();
        s.s[3] = -2;
        let _ = s.gather_state(&corpus);
    }

    #[test]
    fn virtual_clock_is_monotone_across_epochs() {
        let corpus = preset("tiny").unwrap();
        let mut s = sim(&corpus, 4, 3);
        s.run_epoch();
        let a = s.vtime_secs();
        s.run_epoch();
        let b = s.vtime_secs();
        assert!(b > a);
    }
}
