//! The compute cost model: virtual ns charged for each unit of real work a
//! simulated worker performs.
//!
//! Constants are *calibrated against this machine's real serial sampler*
//! (`fnomad-lda calibrate` measures F+LDA(word) ns/token and prints a
//! CostModel; the defaults below come from that measurement) so a 1-worker
//! simulation reproduces real single-thread wall clock, and p-worker
//! numbers are "p of these cores plus the network".

use crate::corpus::Corpus;
use crate::lda::state::{Hyper, LdaState};
use crate::lda::{FLdaWord, Sweep};
use crate::util::rng::Pcg32;

/// Per-operation virtual-time charges (ns).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// one token resample inside a word subtask (dec + r build + two-level
    /// draw + inc + 2 tree updates); dominated by Θ(|T_d| + log T)
    pub token_ns: f64,
    /// raising/lowering one support topic on subtask entry/exit
    pub support_ns: f64,
    /// F+tree full rebuild, per topic (global-token arrival)
    pub rebuild_ns_per_topic: f64,
    /// parameter-server service time per row pulled/pushed
    pub server_ns_per_word: f64,
    /// extra per-token cost when streaming state from disk (Yahoo!LDA(D))
    pub disk_ns_per_token: f64,
}

impl CostModel {
    /// Defaults for a given topic count, from the calibration measurement
    /// on this machine (token cost grows ~ a + b·log2 T).
    pub fn default_for(t: usize) -> CostModel {
        let log_t = (t.max(2) as f64).log2();
        CostModel {
            token_ns: 140.0 + 28.0 * log_t,
            support_ns: 16.0,
            rebuild_ns_per_topic: 4.0,
            server_ns_per_word: 250.0,
            disk_ns_per_token: 600.0,
        }
    }

    /// Calibrate `token_ns` by timing the real serial word-major sampler
    /// on (a slice of) the target corpus.
    pub fn calibrate(corpus: &Corpus, hyper: Hyper, sweeps: usize) -> CostModel {
        let mut rng = Pcg32::seeded(0xCA11B);
        let mut state = LdaState::init_random(corpus, hyper, &mut rng);
        let mut sampler = FLdaWord::new(&state, corpus);
        // warm-up sweep (allocation, cache effects)
        sampler.sweep(&mut state, corpus, &mut rng);
        let t0 = std::time::Instant::now();
        for _ in 0..sweeps.max(1) {
            sampler.sweep(&mut state, corpus, &mut rng);
        }
        let ns = t0.elapsed().as_nanos() as f64
            / (sweeps.max(1) * corpus.num_tokens()) as f64;
        CostModel { token_ns: ns, ..CostModel::default_for(hyper.t) }
    }

    /// Virtual duration of one word subtask.  A token with no local
    /// occurrences is checked and forwarded without touching the tree
    /// (the worker code early-returns), so it costs only the check.
    pub fn word_task_ns(&self, occurrences: usize, support: usize) -> u64 {
        if occurrences == 0 {
            return 60;
        }
        (self.token_ns * occurrences as f64 + self.support_ns * (2 * support) as f64)
            .round() as u64
    }

    /// Virtual duration of a global-token fold (tree rebuild).
    pub fn global_task_ns(&self, t: usize) -> u64 {
        (self.rebuild_ns_per_topic * t as f64).round() as u64
    }

    /// Server service time for an op touching `words` rows.
    pub fn server_service_ns(&self, words: usize) -> u64 {
        (self.server_ns_per_word * words.max(1) as f64).round() as u64
    }

    /// Compute time for a PS batch of `tokens` (+ disk surcharge if the
    /// disk flavor is simulated).
    pub fn batch_compute_ns(&self, tokens: usize, disk: bool) -> u64 {
        let per = self.token_ns + if disk { self.disk_ns_per_token } else { 0.0 };
        (per * tokens as f64).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    #[test]
    fn defaults_scale_with_topics() {
        let small = CostModel::default_for(128);
        let large = CostModel::default_for(8192);
        assert!(large.token_ns > small.token_ns);
    }

    #[test]
    fn word_task_cost_is_linear_in_occurrences() {
        let m = CostModel::default_for(1024);
        let one = m.word_task_ns(1, 4);
        let hundred = m.word_task_ns(100, 4);
        assert!(hundred > 50 * one / 2);
        assert_eq!(m.word_task_ns(0, 99), 60); // empty subtask = check + forward
    }

    #[test]
    fn calibration_runs_and_is_positive() {
        let corpus = preset("tiny").unwrap();
        let m = CostModel::calibrate(&corpus, Hyper::paper_default(16), 1);
        assert!(m.token_ns > 0.0 && m.token_ns < 1e6, "token_ns {}", m.token_ns);
    }

    #[test]
    fn disk_flavor_costs_more() {
        let m = CostModel::default_for(1024);
        assert!(m.batch_compute_ns(1000, true) > m.batch_compute_ns(1000, false));
    }
}
