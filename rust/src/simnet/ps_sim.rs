//! The parameter-server baseline under virtual time — Yahoo!LDA(M) and
//! Yahoo!LDA(D) of Figs. 5–6.
//!
//! Workers run the *real* cached-batch sampler
//! ([`crate::ps::worker::PsWorkerState::process_batch`]); the simulator
//! charges pull round-trips, sharded-server service time, push transfers
//! and (for the disk flavor) the per-token streaming surcharge.  The
//! server is sharded one shard per machine (Yahoo! LDA's distributed ICE
//! store); each shard is a FIFO queue — the queueing delay under p
//! clients is exactly the central-coordination bottleneck the paper's
//! Nomad design removes.

use crate::coordinator::EpochReport;
use crate::corpus::{Corpus, Partition};
use crate::lda::state::{assemble_state, checked_totals, Hyper, LdaState, SparseCounts};
use crate::ps::worker::PsWorkerState;
use crate::util::rng::Pcg32;

use super::{ClusterSpec, CostModel, EventQueue};

/// Simulated-PS configuration.
#[derive(Clone, Debug)]
pub struct PsSimConfig {
    pub cluster: ClusterSpec,
    pub cost: CostModel,
    pub seed: u64,
    /// pull/push cadence in documents
    pub batch_docs: usize,
    /// Yahoo!LDA(D): charge the disk-streaming surcharge
    pub disk: bool,
}

impl PsSimConfig {
    pub fn new(cluster: ClusterSpec, t: usize) -> Self {
        PsSimConfig {
            cluster,
            cost: CostModel::default_for(t),
            seed: 0,
            batch_docs: 16,
            disk: false,
        }
    }
}

enum Event {
    /// worker w's pull request reaches shard s
    PullArrive { worker: usize, shard: usize },
    /// shard finished serving w's pull; response heads back
    PullServed { worker: usize, shard: usize },
    /// pull response reaches the worker: compute the batch
    PullResponse { worker: usize },
    /// batch compute done: send push, then next pull (or finish)
    ComputeDone { worker: usize },
    /// push applied at the shard
    PushArrive { shard: usize, pushes: Vec<(u32, Vec<(u16, i32)>)>, nt_delta: Vec<i64> },
}

/// The simulated PS cluster.
pub struct PsSim {
    workers: Vec<PsWorkerState>,
    /// authoritative server state (sharding is a *timing* construct; the
    /// data is one logical store)
    nwt: Vec<SparseCounts>,
    nt: Vec<i64>,
    /// per-shard busy horizon
    shard_busy: Vec<u64>,
    cfg: PsSimConfig,
    hyper: Hyper,
    now: u64,
    pub epochs_run: usize,
    processed_total: u64,
    // per-epoch scratch
    batch_of: Vec<usize>,
    wait_ns_sum: f64,
    wait_ops: u64,
}

impl PsSim {
    /// Build from a random initial state (see [`Self::from_state`]).
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: PsSimConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x5EED);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, &state, cfg)
    }

    /// Build from explicit initial assignments (the resume path).
    pub fn from_state(corpus: &Corpus, init: &LdaState, cfg: PsSimConfig) -> Self {
        let p = cfg.cluster.total_workers();
        // offsets equality (not just doc count) — see NomadRuntime::from_state
        assert_eq!(init.doc_offsets.as_slice(), corpus.offsets(), "init state / corpus mismatch");
        let hyper = init.hyper;
        let partition = Partition::by_tokens(corpus, p);
        // worker streams derive from a different stream id than the init
        // draws (0x5EED in `new`), so sampling never replays them
        let mut seed_rng = Pcg32::new(cfg.seed, 0xDEE5);

        let nwt = init.nwt.clone();
        let nt: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();

        let mut workers = Vec::with_capacity(p);
        for l in 0..p {
            let (start, end) = partition.ranges[l];
            workers.push(PsWorkerState::new(
                l,
                corpus.read_range(start, end),
                hyper,
                init.z_range(start, end).to_vec(),
                cfg.batch_docs,
                seed_rng.split(l as u64 + 1),
            ));
        }

        // Yahoo!LDA's ICE store is distributed across machines AND
        // multi-threaded within one: model at least 4 service lanes so a
        // single-node PS is not artificially serialized (otherwise shard
        // saturation masks every other effect, e.g. the disk surcharge).
        let shards = cfg.cluster.machines.clamp(4, cfg.cluster.total_workers().max(4));
        PsSim {
            workers,
            nwt,
            nt,
            shard_busy: vec![0; shards],
            cfg,
            hyper,
            now: 0,
            epochs_run: 0,
            processed_total: 0,
            batch_of: vec![0; p],
            wait_ns_sum: 0.0,
            wait_ops: 0,
        }
    }

    fn shard_of(&self, worker: usize) -> usize {
        // a worker talks to the shard co-resident with its machine's data
        // range; hashing by worker spreads load like Yahoo!LDA's ICE
        worker % self.shard_busy.len()
    }

    /// Serve an op at a shard: FIFO queue + service time; returns when the
    /// op completes and accumulates queue-wait telemetry.
    fn shard_serve(&mut self, shard: usize, arrival: u64, service: u64) -> u64 {
        let start = arrival.max(self.shard_busy[shard]);
        self.wait_ns_sum += (start - arrival) as f64;
        self.wait_ops += 1;
        self.shard_busy[shard] = start + service;
        start + service
    }

    /// network time worker <-> its shard (server lives on machine 0 side
    /// of each shard; cross-machine unless the worker is on the shard's
    /// machine)
    fn net_ns(&self, worker: usize, shard: usize, bytes: usize) -> u64 {
        let wm = self.cfg.cluster.machine_of(worker);
        if wm == shard % self.cfg.cluster.machines {
            self.cfg.cluster.intra_latency_ns
        } else {
            let workers = self.cfg.cluster.total_workers().max(1);
            let shard_home = shard * self.cfg.cluster.cores_per_machine % workers;
            self.cfg.cluster.transfer_ns(bytes, worker, shard_home)
        }
    }

    pub fn run_epoch(&mut self) -> EpochReport {
        let p = self.workers.len();
        let epoch_start = self.now;
        let mut queue: EventQueue<Event> = EventQueue::new();
        self.batch_of = vec![0; p];
        self.wait_ns_sum = 0.0;
        self.wait_ops = 0;
        let mut done = 0usize;
        let mut processed = 0u64;
        let mut pulls = 0u64;

        // every worker issues its first pull
        for w in 0..p {
            let shard = self.shard_of(w);
            let words = self.workers[w].batch_words(0);
            let bytes = 4 * words.len();
            let dt = self.net_ns(w, shard, bytes);
            queue.schedule(self.now + dt, Event::PullArrive { worker: w, shard });
        }

        while done < p {
            let (t, ev) = queue.pop().expect("ps sim starved");
            self.now = t;
            match ev {
                Event::PullArrive { worker, shard } => {
                    pulls += 1;
                    let b = self.batch_of[worker];
                    let nwords = self.workers[worker].batch_words(b).len();
                    let svc = self.cfg.cost.server_service_ns(nwords);
                    let served_at = self.shard_serve(shard, t, svc);
                    queue.schedule(served_at, Event::PullServed { worker, shard });
                }
                Event::PullServed { worker, shard } => {
                    let b = self.batch_of[worker];
                    // response payload ≈ rows' support
                    let words = self.workers[worker].batch_words(b);
                    let bytes: usize =
                        words.iter().map(|&w| 6 * self.nwt[w as usize].support() + 8).sum();
                    let dt = self.net_ns(worker, shard, bytes);
                    queue.schedule(self.now + dt, Event::PullResponse { worker });
                }
                Event::PullResponse { worker } => {
                    let b = self.batch_of[worker];
                    let tokens = self.workers[worker].batch_tokens(b);
                    let dur = self.cfg.cost.batch_compute_ns(tokens, self.cfg.disk);
                    queue.schedule(self.now + dur, Event::ComputeDone { worker });
                }
                Event::ComputeDone { worker } => {
                    // the *real* sampling happens here, against the server
                    // state as of now (models the stale window: concurrent
                    // pushes that landed during compute were not visible)
                    let b = self.batch_of[worker];
                    let words = self.workers[worker].batch_words(b);
                    let rows: Vec<SparseCounts> =
                        words.iter().map(|&w| self.nwt[w as usize].clone()).collect();
                    let out = self.workers[worker].process_batch(
                        b,
                        &words,
                        rows,
                        self.nt.clone(),
                    );
                    processed += out.processed;
                    let shard = self.shard_of(worker);
                    let bytes: usize =
                        out.pushes.iter().map(|(_, d)| 6 * d.len() + 8).sum();
                    let dt = self.net_ns(worker, shard, bytes);
                    queue.schedule(self.now + dt, Event::PushArrive {
                        shard,
                        pushes: out.pushes,
                        nt_delta: out.nt_delta,
                    });
                    // fire-and-forget push: the worker proceeds immediately
                    self.batch_of[worker] += 1;
                    if self.batch_of[worker] >= self.workers[worker].num_batches() {
                        done += 1;
                    } else {
                        let nb = self.batch_of[worker];
                        let nwords = self.workers[worker].batch_words(nb).len();
                        let dt = self.net_ns(worker, shard, 4 * nwords);
                        queue.schedule(self.now + dt, Event::PullArrive { worker, shard });
                    }
                }
                Event::PushArrive { shard, pushes, nt_delta } => {
                    let svc = self.cfg.cost.server_service_ns(pushes.len());
                    let _ = self.shard_serve(shard, t, svc);
                    // apply at service time (single-threaded sim: now)
                    for (w, deltas) in &pushes {
                        let row = &mut self.nwt[*w as usize];
                        for &(topic, d) in deltas {
                            match d.cmp(&0) {
                                std::cmp::Ordering::Greater => {
                                    for _ in 0..d {
                                        row.inc(topic);
                                    }
                                }
                                std::cmp::Ordering::Less => {
                                    for _ in 0..(-d) {
                                        if row.get(topic) > 0 {
                                            row.dec(topic);
                                        }
                                    }
                                }
                                std::cmp::Ordering::Equal => {}
                            }
                        }
                    }
                    for (acc, d) in self.nt.iter_mut().zip(nt_delta) {
                        *acc += d;
                    }
                }
            }
        }

        // drain in-flight pushes so the epoch boundary is exact
        while let Some((t, ev)) = queue.pop() {
            self.now = t;
            if let Event::PushArrive { shard, pushes, nt_delta } = ev {
                let svc = self.cfg.cost.server_service_ns(pushes.len());
                let _ = self.shard_serve(shard, t, svc);
                for (w, deltas) in &pushes {
                    let row = &mut self.nwt[*w as usize];
                    for &(topic, d) in deltas {
                        match d.cmp(&0) {
                            std::cmp::Ordering::Greater => {
                                for _ in 0..d {
                                    row.inc(topic);
                                }
                            }
                            std::cmp::Ordering::Less => {
                                for _ in 0..(-d) {
                                    if row.get(topic) > 0 {
                                        row.dec(topic);
                                    }
                                }
                            }
                            std::cmp::Ordering::Equal => {}
                        }
                    }
                }
                for (acc, d) in self.nt.iter_mut().zip(nt_delta) {
                    *acc += d;
                }
            }
        }

        self.epochs_run += 1;
        self.processed_total += processed;
        EpochReport {
            processed,
            secs: (self.now - epoch_start) as f64 / 1e9,
            // every pull refreshes a cache against a server that concurrent
            // pushes have already moved on from
            stale_reads: pulls,
            msgs: self.wait_ops,
            ring: None,
        }
    }

    pub fn vtime_secs(&self) -> f64 {
        self.now as f64 / 1e9
    }

    /// Mean shard queueing delay per op in the last epoch (ns) — the
    /// central-coordination bottleneck telemetry of Figs. 5–6.
    pub fn mean_server_wait_ns(&self) -> f64 {
        if self.wait_ops > 0 {
            self.wait_ns_sum / self.wait_ops as f64
        } else {
            0.0
        }
    }

    /// Exact global state at epoch boundaries.
    ///
    /// Panics if the server totals contain a negative entry — that is
    /// count-state corruption, not a value to clamp away.
    pub fn gather_state(&mut self, corpus: &Corpus) -> LdaState {
        let parts = self
            .workers
            .iter()
            .map(|w| (w.start_doc(), w.ntd_rows(), w.z_flat()));
        assemble_state(corpus, self.hyper, parts, self.nwt.clone(), checked_totals(&self.nt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::log_likelihood;

    fn mk(corpus: &Corpus, workers: usize, disk: bool) -> PsSim {
        let mut cfg = PsSimConfig::new(ClusterSpec::multicore(workers), 8);
        cfg.batch_docs = 4;
        cfg.disk = disk;
        cfg.seed = 9;
        PsSim::new(corpus, Hyper::paper_default(8), cfg)
    }

    #[test]
    fn ps_sim_trains_consistently() {
        let corpus = preset("tiny").unwrap();
        let mut sim = mk(&corpus, 4, false);
        let ll0 = log_likelihood(&sim.gather_state(&corpus));
        let stats = sim.run_epoch();
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        assert!(stats.stale_reads > 0);
        assert!(stats.msgs >= stats.stale_reads);
        let state = sim.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        for _ in 0..5 {
            sim.run_epoch();
        }
        assert!(log_likelihood(&sim.gather_state(&corpus)) > ll0);
    }

    #[test]
    fn disk_flavor_is_slower() {
        let corpus = preset("tiny").unwrap();
        let m = mk(&corpus, 4, false).run_epoch().secs;
        let d = mk(&corpus, 4, true).run_epoch().secs;
        assert!(d > m, "disk {d} <= mem {m}");
    }

    #[test]
    fn nomad_beats_ps_in_virtual_time() {
        // the headline Fig. 5 shape at tiny scale: same cores, same cost
        // model — nomad's decentralized routing beats the server queue
        let corpus = preset("tiny").unwrap();
        let ps = mk(&corpus, 8, false).run_epoch().secs;
        let mut ncfg = super::super::nomad_sim::NomadSimConfig::new(
            ClusterSpec::multicore(8),
            8,
        );
        ncfg.seed = 9;
        let nomad = super::super::nomad_sim::NomadSim::new(
            &corpus,
            Hyper::paper_default(8),
            ncfg,
        )
        .run_epoch()
        .secs;
        assert!(
            nomad < ps,
            "nomad vtime {nomad} should beat ps {ps}"
        );
    }
}
