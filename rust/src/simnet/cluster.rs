//! Cluster topology model: machines × cores, link latencies, bandwidth.
//!
//! Numbers default to the paper's testbed class (TACC Maverick: 20-core
//! Xeon E5-2680 nodes on FDR InfiniBand ≈ 54 Gb/s, ~1–2 µs MPI latency;
//! we default to a slightly conservative 50 µs + 10 Gb/s to represent
//! commodity clusters, configurable per experiment).

/// Simulated cluster shape.
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub machines: usize,
    pub cores_per_machine: usize,
    /// same-machine worker-to-worker hop (queue handoff)
    pub intra_latency_ns: u64,
    /// cross-machine message latency
    pub inter_latency_ns: u64,
    /// cross-machine link bandwidth (bits/s); intra-machine transfers are
    /// treated as free (shared memory)
    pub inter_bandwidth_bps: f64,
}

impl ClusterSpec {
    /// Single multi-core machine (Fig. 5).
    pub fn multicore(cores: usize) -> ClusterSpec {
        ClusterSpec {
            machines: 1,
            cores_per_machine: cores,
            intra_latency_ns: 200,
            inter_latency_ns: 0,
            inter_bandwidth_bps: f64::INFINITY,
        }
    }

    /// The paper's distributed setting: `machines` × 20 cores (Fig. 6).
    pub fn cluster(machines: usize) -> ClusterSpec {
        ClusterSpec {
            machines,
            cores_per_machine: 20,
            intra_latency_ns: 200,
            inter_latency_ns: 50_000,
            inter_bandwidth_bps: 10e9,
        }
    }

    pub fn total_workers(&self) -> usize {
        self.machines * self.cores_per_machine
    }

    pub fn machine_of(&self, worker: usize) -> usize {
        worker / self.cores_per_machine
    }

    /// Virtual ns to move `bytes` from worker `a` to worker `b`.
    pub fn transfer_ns(&self, bytes: usize, a: usize, b: usize) -> u64 {
        if self.machine_of(a) == self.machine_of(b) {
            self.intra_latency_ns
        } else {
            let wire = if self.inter_bandwidth_bps.is_finite() {
                (bytes as f64 * 8.0 / self.inter_bandwidth_bps * 1e9) as u64
            } else {
                0
            };
            self.inter_latency_ns + wire
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multicore_is_one_machine() {
        let c = ClusterSpec::multicore(20);
        assert_eq!(c.total_workers(), 20);
        assert_eq!(c.machine_of(19), 0);
        assert_eq!(c.transfer_ns(1 << 20, 3, 17), c.intra_latency_ns);
    }

    #[test]
    fn cluster_charges_wire_time() {
        let c = ClusterSpec::cluster(32);
        assert_eq!(c.total_workers(), 640);
        assert_eq!(c.machine_of(20), 1);
        let same = c.transfer_ns(10_000, 0, 19);
        let cross = c.transfer_ns(10_000, 0, 20);
        assert!(cross > same);
        // 10 KB at 10 Gb/s = 8 µs wire + 50 µs latency
        assert_eq!(cross, 50_000 + 8_000);
    }
}
