//! Virtual-time discrete-event execution of the parallel runtimes.
//!
//! **Why this exists** (DESIGN.md §Hardware-Adaptation): the paper's
//! scaling figures need 20-core machines and a 32-node cluster; this
//! session has one core.  The simulator runs the *actual* Gibbs updates —
//! workers mutate real [`crate::nomad::worker::WorkerState`]s, so
//! convergence quality is real, not modeled — while **time** is charged
//! from a calibrated per-token cost model plus a cluster network model.
//! Reported speedups and crossovers are therefore statements about the
//! algorithmic coordination structure (token ring vs. central server),
//! which is exactly what Figs. 5–6 compare; absolute seconds are virtual.
//!
//! * [`cost`] — [`cost::CostModel`]: per-token sampling cost (calibrated
//!   against the real serial sampler by `fnomad-lda calibrate`), tree
//!   maintenance, server service times, the disk-stream surcharge.
//! * [`cluster`] — [`cluster::ClusterSpec`]: machines × cores, intra/inter
//!   latency, link bandwidth.
//! * [`nomad_sim`] — Nomad under virtual time (Figs. 5a-c, 6).
//! * [`ps_sim`] — the parameter-server baseline, memory and disk flavors
//!   (Yahoo!LDA(M)/(D) in Figs. 5–6).

pub mod cluster;
pub mod cost;
pub mod nomad_sim;
pub mod ps_sim;

pub use cluster::ClusterSpec;
pub use cost::CostModel;
pub use nomad_sim::NomadSim;
pub use ps_sim::PsSim;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Discrete-event queue over (virtual ns, tiebreak seq, event).
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(u64, u64, EventBox<E>)>>,
    seq: u64,
}

/// Wrapper that ignores the event payload in Ord (heap needs total order).
struct EventBox<E>(E);

impl<E> PartialEq for EventBox<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EventBox<E> {}
impl<E> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for EventBox<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `event` at absolute virtual time `at_ns`.
    pub fn schedule(&mut self, at_ns: u64, event: E) {
        self.seq += 1;
        self.heap.push(Reverse((at_ns, self.seq, EventBox(event))));
    }

    /// Pop the earliest event: (time, event).
    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e.0))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(5, 1);
        q.schedule(5, 2);
        q.schedule(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }
}
