//! XLA/PJRT integration: the AOT artifacts (JAX + Pallas, compiled to HLO
//! text by `make artifacts`) loaded and executed from Rust, cross-checked
//! against the in-crate reference implementations.
//!
//! These tests only exist when the crate is built with `--features pjrt`;
//! the default build compiles a single loud SKIP test instead, so
//! `cargo test` stays hermetic (no Python, JAX, or XLA artifacts needed).
//! With the feature on, they additionally SKIP (again loudly, never
//! failing) when `artifacts/` is absent; `make test` always builds
//! artifacts first and therefore always exercises them.

#[cfg(not(feature = "pjrt"))]
#[test]
fn skipped_without_pjrt_feature() {
    eprintln!(
        "SKIP: xla_runtime tests are feature-gated — rebuild with \
         `cargo test --features pjrt` (needs the vendored xla crate and \
         `make artifacts`); the default build uses the pure-Rust evaluator"
    );
}

#[cfg(feature = "pjrt")]
mod pjrt_tests {
    use fnomad_lda::corpus::presets::preset;
    use fnomad_lda::corpus::synthetic::{generate, SyntheticSpec};
    use fnomad_lda::lda::state::{Hyper, LdaState};
    use fnomad_lda::lda::{self, Sweep};
    use fnomad_lda::runtime::{
        artifacts_available, default_artifact_dir, LlEvaluator, ProbOracle, PROB_BATCH,
    };
    use fnomad_lda::util::rng::Pcg32;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = default_artifact_dir();
        if artifacts_available(&dir) {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            None
        }
    }

    /// XLA LL == Rust LL across random states and both built topic counts.
    #[test]
    fn xla_ll_matches_rust_reference() {
        let Some(dir) = artifacts() else { return };
        let corpus = preset("tiny").unwrap();
        for &t in &[128usize, 1024] {
            let mut evaluator = LlEvaluator::new(&dir, t).unwrap();
            for seed in 0..3 {
                let mut rng = Pcg32::seeded(seed);
                let state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
                let rust = lda::log_likelihood(&state);
                let xla = evaluator.log_likelihood(&state).unwrap();
                let rel = ((xla - rust) / rust).abs();
                assert!(
                    rel < 2e-4,
                    "T={t} seed={seed}: rust {rust:.6e} xla {xla:.6e} rel {rel:.2e}"
                );
            }
        }
    }

    /// The agreement holds on a *trained* state too (counts far from uniform).
    #[test]
    fn xla_ll_matches_after_training() {
        let Some(dir) = artifacts() else { return };
        let corpus = generate(&SyntheticSpec {
            num_docs: 300,
            vocab: 700,
            avg_doc_len: 50.0,
            true_topics: 10,
            seed: 5,
            ..Default::default()
        });
        let t = 128;
        let mut rng = Pcg32::seeded(1);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
        let mut sampler = lda::FLdaWord::new(&state, &corpus);
        for _ in 0..10 {
            sampler.sweep(&mut state, &corpus, &mut rng);
        }
        let rust = lda::log_likelihood(&state);
        let mut evaluator = LlEvaluator::new(&dir, t).unwrap();
        let xla = evaluator.log_likelihood(&state).unwrap();
        let rel = ((xla - rust) / rust).abs();
        assert!(rel < 2e-4, "rust {rust:.6e} xla {xla:.6e} rel {rel:.2e}");
    }

    /// The Pallas dense-probability artifact agrees with the Rust dense
    /// conditional — the independent oracle for every sampler's target.
    #[test]
    fn prob_artifact_matches_dense_conditional() {
        let Some(dir) = artifacts() else { return };
        let t = 128usize;
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(77);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
        let oracle = ProbOracle::new(&dir, t).unwrap();

        // batch: the first PROB_BATCH tokens of the corpus
        let mut ntd = vec![0f32; PROB_BATCH * t];
        let mut ntw = vec![0f32; PROB_BATCH * t];
        let mut sites = Vec::new();
        'outer: for (doc, tokens) in corpus.docs().enumerate() {
            for &w in tokens.iter() {
                let b = sites.len();
                for k in 0..t {
                    ntd[b * t + k] = state.ntd[doc].get(k as u16) as f32;
                    ntw[b * t + k] = state.nwt[w as usize].get(k as u16) as f32;
                }
                sites.push((doc, w as usize));
                if sites.len() == PROB_BATCH {
                    break 'outer;
                }
            }
        }
        assert_eq!(sites.len(), PROB_BATCH);
        let nt: Vec<f32> = state.nt.iter().map(|&v| v as f32).collect();
        let h = state.hyper;
        let (p, norm) = oracle
            .dense_prob(
                &ntd,
                &ntw,
                &nt,
                h.alpha as f32,
                h.beta as f32,
                h.betabar(state.vocab) as f32,
            )
            .unwrap();

        for (b, &(doc, word)) in sites.iter().enumerate() {
            let want = state.dense_conditional(doc, word);
            let total: f64 = want.iter().sum();
            let got_norm = norm[b] as f64;
            assert!(
                ((got_norm - total) / total).abs() < 1e-4,
                "site {b}: norm {got_norm} vs {total}"
            );
            for k in 0..t {
                let rel = ((p[b * t + k] as f64 - want[k]) / want[k]).abs();
                assert!(rel < 1e-4, "site {b} topic {k}: {} vs {}", p[b * t + k], want[k]);
            }
        }
    }

    /// Loader rejects a topic count with no artifacts.
    #[test]
    fn loader_rejects_unbuilt_topic_count() {
        let Some(dir) = artifacts() else { return };
        let err = match LlEvaluator::new(&dir, 333) {
            Err(e) => e,
            Ok(_) => panic!("loader accepted T=333 with no artifact"),
        };
        assert!(err.contains("333"), "unhelpful error: {err}");
    }
}
