//! Allocation-freedom of the Nomad Algorithm-4 inner loop.
//!
//! A counting global allocator wraps the system allocator.  After a warmup
//! epoch has settled every reusable capacity (the F+tree, the sparse
//! cumsum scratch, the `SparseCounts` rows), re-processing the full word
//! token set through [`WorkerState::process_word_token`] must perform
//! **zero** heap allocations — the property that makes the hot path run
//! at memory bandwidth instead of allocator throughput.
//!
//! This file intentionally holds a single test: the counter is
//! thread-local (each libtest test runs on its own thread, so concurrent
//! tests cannot pollute the measurement), and keeping the binary minimal
//! keeps the measurement honest.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use fnomad_lda::corpus::presets::preset;
use fnomad_lda::lda::state::{Hyper, SparseCounts};
use fnomad_lda::nomad::token::WordToken;
use fnomad_lda::nomad::worker::WorkerState;
use fnomad_lda::util::rng::Pcg32;

struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn alloc_count() -> u64 {
    ALLOCS.with(|c| c.get())
}

#[inline]
fn bump() {
    // try_with: never panic inside the allocator (TLS teardown)
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn process_word_token_is_allocation_free_at_steady_state() {
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(8);

    // single worker owning the whole corpus; flat CSR z + word tokens
    let mut rng = Pcg32::seeded(1);
    let slice = corpus.read_range(0, corpus.num_docs());
    let mut z: Vec<u16> = Vec::with_capacity(corpus.num_tokens());
    let mut nwt: Vec<SparseCounts> =
        (0..corpus.vocab()).map(|_| SparseCounts::with_capacity(hyper.t)).collect();
    let mut s = vec![0i64; hyper.t];
    for &w in &slice.tokens {
        let topic = rng.below(hyper.t) as u16;
        nwt[w as usize].inc(topic);
        s[topic as usize] += 1;
        z.push(topic);
    }
    let mut worker = WorkerState::new(0, 1, &slice, hyper, z, s, Pcg32::seeded(2));
    let mut tokens: Vec<WordToken> = nwt
        .into_iter()
        .enumerate()
        .map(|(w, c)| WordToken::new(w as u32, c))
        .collect();

    // warmup: two full epochs settle every reusable capacity (ntd rows
    // were created with doc-length capacity; token rows were preallocated
    // at T; the cumsum scratch grows to the max doc support once)
    for _ in 0..2 {
        for tok in tokens.iter_mut() {
            worker.process_word_token(tok);
        }
    }

    // measured epoch: the Algorithm-4 inner loop must not allocate
    let before = alloc_count();
    let mut processed = 0usize;
    for tok in tokens.iter_mut() {
        processed += worker.process_word_token(tok);
    }
    let after = alloc_count();
    assert_eq!(processed, corpus.num_tokens(), "epoch did not cover the corpus");
    assert_eq!(
        after - before,
        0,
        "process_word_token allocated {} times during a steady-state epoch",
        after - before
    );
}
