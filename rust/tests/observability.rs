//! Observability acceptance: the metrics registry snapshots
//! deterministically under concurrent writers, `--metrics` emits
//! schema-valid JSONL, `--trace` emits a well-formed Chrome-trace-event
//! file, telemetry never perturbs the training trajectory, and a real
//! kill-and-recover run leaves a machine-readable recovery timeline in
//! the right order.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::thread;

use fnomad_lda::coordinator::{train, EvalPolicy, RuntimeKind, TrainConfig, TrainResult};
use fnomad_lda::obs::registry::Registry;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fnomad_observability_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Extract the integer value of `"key":N` from a JSON line (the exporter
/// writes unquoted integers for its integral fields).
fn field_u64(line: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat).unwrap_or_else(|| panic!("{line} missing {pat}"));
    line[at + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("{pat} in {line} is not an integer"))
}

/// Timestamp (`"ts":N`, µs) of the first trace event named `name`.
fn event_ts(trace_body: &str, name: &str) -> u64 {
    let pat = format!("\"name\":\"{name}\"");
    let at = trace_body
        .find(&pat)
        .unwrap_or_else(|| panic!("trace has no {name:?} event: {trace_body}"));
    let obj = &trace_body[at..trace_body[at..].find('}').map_or(trace_body.len(), |e| at + e)];
    field_u64(obj, "ts")
}

/// The registry contract the JSONL exporter leans on: after writers
/// quiesce, counter totals are exact and two snapshots of the same state
/// are identical, with keys in sorted order.
#[test]
fn registry_snapshot_is_deterministic_under_concurrent_writers() {
    const THREADS: u64 = 8;
    const OPS: u64 = 10_000;
    let reg = Arc::new(Registry::new());
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let reg = Arc::clone(&reg);
        handles.push(thread::spawn(move || {
            // get-or-create races with the other threads by design
            let c = reg.counter("w.ops");
            let g = reg.gauge("w.level");
            let h = reg.histogram("w.lat");
            for i in 0..OPS {
                c.inc();
                g.set(t * OPS + i);
                h.record_ns(1 << (t % 20));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap1 = reg.snapshot();
    let snap2 = reg.snapshot();
    assert_eq!(snap1, snap2, "quiescent snapshots must be byte-identical");
    let names: Vec<&str> = snap1.iter().map(|(n, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "snapshot keys must come out sorted");
    let get = |k: &str| snap1.iter().find(|(n, _)| n == k).unwrap_or_else(|| panic!("no {k}")).1;
    assert_eq!(get("w.ops"), (THREADS * OPS) as f64, "dropped counter increments");
    assert_eq!(get("w.lat.count"), (THREADS * OPS) as f64, "dropped histogram records");
    // the gauge holds one of the written values (last-write-wins)
    assert!(get("w.level") < (THREADS * OPS) as f64);
}

/// One test, not three: trace recording is a sticky process-global
/// switch, so the untraced baseline must run first and all trace-file
/// assertions must live on this side of the enable.
///
/// Covers: telemetry is zero-perturbation (bit-identical LL trajectory
/// with and without `--metrics`/`--trace`), the JSONL schema, and the
/// trace file's shape.
#[test]
fn telemetry_export_is_valid_and_does_not_perturb_training() {
    let base = || {
        TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .workers(2)
            .topics(8)
            .iters(3)
            .eval(EvalPolicy::Rust)
            .quiet(true)
    };
    let plain = train(&base()).unwrap();

    let dir = tmpdir("export");
    let metrics = dir.join("metrics.jsonl");
    let trace = dir.join("trace.json");
    let traced = train(&base().metrics(&metrics).trace(&trace)).unwrap();

    let bits = |r: &TrainResult| -> Vec<(u64, u64)> {
        r.ll_vs_iter.points.iter().map(|&(x, y)| (x.to_bits(), y.to_bits())).collect()
    };
    assert_eq!(
        bits(&plain),
        bits(&traced),
        "telemetry flags perturbed the fixed-seed LL trajectory"
    );

    // --metrics: one complete JSON object per epoch, required keys on
    // every line, epoch and processed_total monotone
    let body = std::fs::read_to_string(&metrics).unwrap();
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), 3, "one JSONL line per epoch: {body}");
    let mut prev_total = 0;
    for (i, line) in lines.iter().enumerate() {
        assert!(
            line.starts_with("{\"epoch\":") && line.ends_with('}'),
            "not a JSON object line: {line}"
        );
        for key in ["secs", "processed", "processed_total"] {
            assert!(line.contains(&format!("\"{key}\":")), "{line} missing {key}");
        }
        assert_eq!(field_u64(line, "epoch"), (i + 1) as u64);
        let total = field_u64(line, "processed_total");
        assert!(total >= prev_total, "processed_total regressed: {body}");
        prev_total = total;
    }
    assert!(prev_total > 0, "no tokens were ever reported processed");
    // a nomad run exports the ring breakdown and the registry snapshot
    assert!(body.contains("\"ring.inject_secs\":"), "no ring telemetry: {body}");
    assert!(body.contains("\"slot.0.sample_secs\":"), "no per-slot breakdown: {body}");
    assert!(body.contains("\"train.epochs_total\":"), "no registry snapshot: {body}");

    // --trace: well-formed Chrome-trace JSON with epoch + slot spans
    let tbody = std::fs::read_to_string(&trace).unwrap();
    assert!(tbody.starts_with("{\"traceEvents\":["), "bad trace head: {tbody}");
    assert!(tbody.trim_end().ends_with("]}"), "bad trace tail: {tbody}");
    assert!(tbody.contains("\"ph\":\"X\""), "no complete events: {tbody}");
    assert!(tbody.contains("\"name\":\"epoch 1\""), "no epoch span: {tbody}");
    assert!(tbody.contains("\"name\":\"slot 0 sample\""), "no slot span: {tbody}");
    assert!(tbody.contains("\"cat\":\"slot\""), "slot spans lost their category: {tbody}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Two real processes through the CLI: the worker kills itself mid-epoch
/// and the surviving trainer must (a) log the failure before the
/// recovery in its event stream — as JSONL, since `--log-json` is on —
/// (b) leave `ring failure` → `reload checkpoint` → `respawn ring` spans
/// in timestamp order in the trace file, and (c) keep the metrics file
/// schema-valid across the restart.
#[test]
fn kill_and_recover_emits_an_ordered_recovery_timeline() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    let mut worker = Command::new(bin)
        .args(["serve-worker", "--listen", "127.0.0.1:0", "--once", "--quiet"])
        .args(["--fail-after-epochs", "1"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve-worker");
    let mut banner = String::new();
    BufReader::new(worker.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-worker banner: {banner:?}"))
        .to_string();

    let dir = tmpdir("chaos");
    let metrics = dir.join("metrics.jsonl");
    let trace = dir.join("trace.json");
    let out = Command::new(bin)
        .args(["train", "--preset", "tiny", "--topics", "8", "--iters", "4"])
        .args(["--runtime", "nomad", "--workers", "1", "--remote", &addr])
        .args(["--eval", "rust", "--quiet", "--log-json"])
        .args(["--checkpoint-dir", dir.join("ckpt").to_str().unwrap()])
        .args(["--max-restarts", "2"])
        .args(["--metrics", metrics.to_str().unwrap()])
        .args(["--trace", trace.to_str().unwrap()])
        .output()
        .expect("run train");
    assert!(
        out.status.success(),
        "train failed: {}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // (a) the event stream: JSONL lines, failure before recovery
    let stderr = String::from_utf8_lossy(&out.stderr);
    for line in stderr.lines().filter(|l| !l.trim().is_empty()) {
        assert!(
            line.starts_with('{') && line.ends_with('}'),
            "--log-json stderr line is not a JSON object: {line}"
        );
        assert!(line.contains("\"level\":"), "event line has no level: {line}");
        assert!(line.contains("\"msg\":"), "event line has no msg: {line}");
    }
    let failed = stderr.find("ring failure:").expect("no ring-failure event");
    let recovered =
        stderr.find("recovered: restarted from epoch").expect("no recovery event");
    assert!(failed < recovered, "recovery logged before the failure:\n{stderr}");

    // (b) the trace timeline, in order
    let tbody = std::fs::read_to_string(&trace).unwrap();
    let t_fail = event_ts(&tbody, "ring failure");
    let t_reload = event_ts(&tbody, "reload checkpoint");
    let t_respawn = event_ts(&tbody, "respawn ring");
    assert!(
        t_fail <= t_reload && t_reload <= t_respawn,
        "recovery spans out of order: failure@{t_fail} reload@{t_reload} \
         respawn@{t_respawn}\n{tbody}"
    );
    assert!(tbody.contains("\"cat\":\"recovery\""), "recovery spans lost their category");

    // (c) metrics survived the restart: still one valid line per epoch,
    // and the restart counter landed in the registry snapshot
    let body = std::fs::read_to_string(&metrics).unwrap();
    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.len(), 4, "one JSONL line per epoch: {body}");
    for line in &lines {
        assert!(line.starts_with("{\"epoch\":") && line.ends_with('}'), "bad line: {line}");
    }
    assert!(field_u64(lines[3], "train.ring_failures") >= 1, "restart never counted: {body}");

    // the worker self-terminated (exit 9); just reap it
    let _ = worker.wait();
    let _ = std::fs::remove_dir_all(&dir);
}
