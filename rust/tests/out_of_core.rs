//! Out-of-core training end to end: the same `.fncorpus` file trained
//! through both corpus backends must produce bit-identical models, and the
//! streaming backend must hold only its bounded read window resident.
//!
//! The backends share one code path for everything *above* the corpus
//! (`docs_in` sweeps, `read_range` worker slices), so bit-identity is the
//! sharpest possible check that the Disk backend returns exactly the bytes
//! the Ram backend holds — any drift in window arithmetic or decode order
//! would flip an RNG draw and diverge the trajectory immediately.

use std::path::PathBuf;

use fnomad_lda::coordinator::{train, EvalPolicy, RuntimeKind, SamplerKind, TrainConfig};
use fnomad_lda::corpus::synthetic::{generate_with, SyntheticSpec};
use fnomad_lda::corpus::{
    peak_resident_corpus_bytes, preset, reset_peak_resident_corpus_bytes, Corpus, FncorpusWriter,
};
use fnomad_lda::lda::{self, Hyper, LdaState, Sweep};
use fnomad_lda::util::rng::Pcg32;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fnomad_out_of_core_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn ram_and_disk_training_are_bit_identical() {
    let corpus = preset("tiny").unwrap();
    let path = tmp("tiny_bitident.fncorpus");
    corpus.write_fncorpus(&path).unwrap();

    let ckpt_ram = tmp("bitident_ram.ckpt");
    let ckpt_disk = tmp("bitident_disk.ckpt");
    let _ = std::fs::remove_file(&ckpt_ram);
    let _ = std::fs::remove_file(&ckpt_disk);

    let base = |ckpt: &PathBuf| {
        TrainConfig::preset("unused-when-corpus-is-set")
            .corpus(&path)
            .topics(8)
            .runtime(RuntimeKind::Serial)
            .sampler(SamplerKind::Sparse)
            .iters(3)
            .seed(17)
            .eval(EvalPolicy::Rust)
            .quiet(true)
            .checkpoint(ckpt.clone())
    };
    let ram = train(&base(&ckpt_ram).corpus_ram(true)).unwrap();
    // a 512-token window forces many window refills per sweep on the
    // ~3.6k-token corpus — the arithmetic gets exercised, not bypassed
    let disk = train(&base(&ckpt_disk).corpus_window(512)).unwrap();

    assert_eq!(ram.ll_vs_iter.points.len(), disk.ll_vs_iter.points.len());
    for (a, b) in ram.ll_vs_iter.points.iter().zip(&disk.ll_vs_iter.points) {
        assert_eq!(a.0, b.0);
        assert_eq!(
            a.1.to_bits(),
            b.1.to_bits(),
            "LL trajectory diverged between backends at iter {}: {} vs {}",
            a.0,
            a.1,
            b.1
        );
    }
    let a = std::fs::read(&ckpt_ram).unwrap();
    let b = std::fs::read(&ckpt_disk).unwrap();
    assert_eq!(a, b, "final checkpoint bytes differ between Ram and DiskCsr");
}

#[test]
fn nomad_workers_slice_a_streamed_corpus() {
    let corpus = preset("tiny").unwrap();
    let path = tmp("tiny_nomad.fncorpus");
    corpus.write_fncorpus(&path).unwrap();

    let cfg = TrainConfig::preset("unused-when-corpus-is-set")
        .corpus(&path)
        .corpus_window(256)
        .topics(8)
        .runtime(RuntimeKind::Nomad)
        .workers(3)
        .iters(2)
        .seed(5)
        .eval(EvalPolicy::Rust)
        .quiet(true);
    let res = train(&cfg).unwrap();
    // the gathered state must be consistent against the equivalent
    // in-RAM corpus: same documents, same offsets
    res.final_state.check_consistency(&corpus).unwrap();
    let lls: Vec<f64> = res.ll_vs_iter.points.iter().map(|&(_, y)| y).collect();
    assert!(lls.last().unwrap() > lls.first().unwrap(), "no improvement: {lls:?}");
}

#[test]
fn streamed_sweep_stays_under_the_read_window_cap() {
    // ~360k tokens => ~1.4 MiB of token payload on disk
    let spec = SyntheticSpec {
        name: "window-cap".into(),
        num_docs: 6_000,
        vocab: 2_000,
        avg_doc_len: 60.0,
        true_topics: 8,
        seed: 33,
        ..Default::default()
    };
    let path = tmp("window_cap.fncorpus");
    let mut w = FncorpusWriter::create(&path, spec.vocab, Vec::new(), &spec.name).unwrap();
    generate_with(&spec, |d| w.push_doc(d)).unwrap();
    let summary = w.finish().unwrap();
    let payload_bytes = summary.num_tokens * 4;

    // cap the window far below the file: 8k tokens = 32 KiB resident
    const WINDOW_TOKENS: usize = 8_192;
    const CAP_BYTES: usize = 256 * 1024;
    assert!(
        payload_bytes > 4 * CAP_BYTES,
        "corpus too small to prove anything: payload {payload_bytes} bytes"
    );

    let corpus = Corpus::open_fncorpus(&path, WINDOW_TOKENS).unwrap();
    reset_peak_resident_corpus_bytes();

    let hyper = Hyper::paper_default(8);
    let mut rng = Pcg32::seeded(3);
    let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
    let mut sampler = lda::by_name("sparse", &state, &corpus).unwrap();
    sampler.sweep(&mut state, &corpus, &mut rng);
    state.check_consistency(&corpus).unwrap();

    let peak = peak_resident_corpus_bytes();
    assert!(peak > 0, "the streamed sweep never charged the resident meter");
    assert!(
        peak <= CAP_BYTES,
        "peak resident corpus bytes {peak} exceeded the {CAP_BYTES}-byte cap \
         (window is {WINDOW_TOKENS} tokens)"
    );
}
