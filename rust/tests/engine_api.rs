//! Integration tests for the typed engine API: enum round-trips, observer
//! callback cadence, and checkpoint/resume through the unified driver.

use fnomad_lda::coordinator::{
    train, train_with, EpochReport, EvalPoint, EvalPolicy, RuntimeKind, SamplerKind,
    TrainConfig, TrainObserver, TrainResult,
};
use fnomad_lda::corpus::preset;

fn tiny(runtime: RuntimeKind) -> TrainConfig {
    TrainConfig::preset("tiny")
        .runtime(runtime)
        .topics(8)
        .iters(2)
        .eval(EvalPolicy::Rust)
        .quiet(true)
}

#[test]
fn enums_roundtrip_fromstr_display() {
    for kind in RuntimeKind::ALL {
        assert_eq!(kind.to_string().parse::<RuntimeKind>().unwrap(), kind);
    }
    for kind in SamplerKind::ALL {
        assert_eq!(kind.to_string().parse::<SamplerKind>().unwrap(), kind);
    }
    for policy in EvalPolicy::ALL {
        assert_eq!(policy.to_string().parse::<EvalPolicy>().unwrap(), policy);
    }
}

#[test]
fn parse_errors_enumerate_valid_names() {
    let err = "nope".parse::<RuntimeKind>().unwrap_err();
    for kind in RuntimeKind::ALL {
        assert!(err.contains(kind.name()), "runtime error must list '{kind}': {err}");
    }
    let err = "nope".parse::<SamplerKind>().unwrap_err();
    for kind in SamplerKind::ALL {
        assert!(err.contains(kind.name()), "sampler error must list '{kind}': {err}");
    }
    let err = "nope".parse::<EvalPolicy>().unwrap_err();
    for policy in EvalPolicy::ALL {
        assert!(err.contains(policy.name()), "eval error must list '{policy}': {err}");
    }
}

#[test]
fn every_sampler_kind_is_buildable() {
    // guards the SamplerKind::name() <-> lda::by_name registry sync
    for kind in SamplerKind::ALL {
        let cfg = tiny(RuntimeKind::Serial).sampler(kind).iters(1);
        train(&cfg).unwrap_or_else(|e| panic!("{kind}: {e}"));
    }
}

#[test]
fn resume_and_save_every_require_checkpoint() {
    assert!(train(&tiny(RuntimeKind::Serial).resume(true)).is_err());
    assert!(train(&tiny(RuntimeKind::Serial).save_every(2)).is_err());
}

#[test]
fn zero_workers_is_a_config_error_not_an_assertion() {
    // `--workers 0` used to die on `assert!(cfg.workers >= 1)` inside
    // NomadRuntime::from_state; it must be a proper driver error naming
    // the flag, for every worker-driven runtime
    for rt in [RuntimeKind::Nomad, RuntimeKind::Ps, RuntimeKind::AdLda, RuntimeKind::NomadSim] {
        let err = train(&tiny(rt).workers(0)).unwrap_err();
        assert!(err.contains("--workers"), "{rt}: error must name the flag: {err}");
    }
}

#[test]
fn remote_flag_requires_the_nomad_runtime() {
    let cfg = tiny(RuntimeKind::Serial).remote(vec!["127.0.0.1:7777".into()]);
    let err = train(&cfg).unwrap_err();
    assert!(err.contains("--remote"), "error must name the flag: {err}");
    assert!(err.contains("nomad"), "error must name the required runtime: {err}");
}

#[test]
fn unreachable_remote_worker_is_a_construction_error() {
    // 127.0.0.1:1 is essentially never listening; the engine build must
    // fail with the address in the message instead of panicking
    let cfg = tiny(RuntimeKind::Nomad).workers(1).remote(vec!["127.0.0.1:1".into()]);
    let err = train(&cfg).unwrap_err();
    assert!(err.contains("127.0.0.1:1"), "error must name the address: {err}");
}

/// Counts every callback the driver fires.
#[derive(Default)]
struct CountingObserver {
    epochs: usize,
    evals: usize,
    eval_epochs: Vec<usize>,
    finishes: usize,
    processed: u64,
}

impl TrainObserver for CountingObserver {
    fn on_epoch(&mut self, _epoch: usize, report: &EpochReport) -> Result<(), String> {
        self.epochs += 1;
        self.processed += report.processed;
        Ok(())
    }

    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        self.evals += 1;
        self.eval_epochs.push(point.epoch);
        Ok(())
    }

    fn on_finish(&mut self, _result: &mut TrainResult) -> Result<(), String> {
        self.finishes += 1;
        Ok(())
    }
}

#[test]
fn observer_sees_exact_eval_cadence() {
    // iters not divisible by eval_every: evals at 0, 2, 4, and the final
    // epoch 5 — exactly iters/eval_every + 2 callbacks
    let iters = 5;
    let eval_every = 2;
    let cfg = tiny(RuntimeKind::Serial).iters(iters).eval_every(eval_every);
    let mut obs = CountingObserver::default();
    train_with(&cfg, &mut [&mut obs as &mut dyn TrainObserver]).unwrap();
    assert_eq!(obs.evals, iters / eval_every + 2, "evals at {:?}", obs.eval_epochs);
    assert_eq!(obs.eval_epochs, vec![0, 2, 4, 5]);
    assert_eq!(obs.epochs, iters);
    assert_eq!(obs.finishes, 1);
    let corpus = preset("tiny").unwrap();
    assert_eq!(obs.processed as usize, iters * corpus.num_tokens());
}

#[test]
fn observer_cadence_holds_on_a_simulated_runtime() {
    let cfg = tiny(RuntimeKind::PsSim).iters(3).eval_every(2);
    let mut obs = CountingObserver::default();
    train_with(&cfg, &mut [&mut obs as &mut dyn TrainObserver]).unwrap();
    assert_eq!(obs.evals, 3 / 2 + 2);
    assert_eq!(obs.eval_epochs, vec![0, 2, 3]);
}

#[test]
fn resume_continues_from_saved_checkpoint() {
    let dir = std::env::temp_dir().join("fnomad_engine_api_resume");
    let ckpt = dir.join("model.ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let corpus = preset("tiny").unwrap();

    // first leg: 3 epochs, checkpoint written at finish
    let first = train(&tiny(RuntimeKind::Serial).iters(3).checkpoint(ckpt.clone())).unwrap();
    let first_final_ll = first.ll_vs_iter.last_y().unwrap();

    // the saved state reloads and is count-consistent with the corpus
    let loaded = fnomad_lda::lda::checkpoint::load(&ckpt, &corpus).unwrap();
    loaded.check_consistency(&corpus).unwrap();
    assert_eq!(loaded.z, first.final_state.z);

    // second leg resumes: its epoch-0 evaluation must equal the first
    // leg's final LL exactly (same state, same evaluator)
    let resume_cfg = tiny(RuntimeKind::Serial).iters(2).checkpoint(ckpt.clone()).resume(true);
    let second = train(&resume_cfg).unwrap();
    let resumed_ll0 = second.ll_vs_iter.points[0].1;
    assert_eq!(resumed_ll0, first_final_ll, "resume did not start from the checkpointed state");
    // and training continued: assignments moved on from the restart point
    // without degrading model quality (Gibbs LL is not strictly monotone)
    assert_ne!(second.final_state.z, loaded.z, "resumed run did not train");
    let last = second.ll_vs_iter.last_y().unwrap();
    assert!(last > resumed_ll0 - 0.01 * resumed_ll0.abs(), "LL collapsed: {last}");
    second.final_state.check_consistency(&corpus).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_rejects_topic_mismatch() {
    // `train --resume --topics 512` against a T=1024 checkpoint must be a
    // loud error, not a silent override of the requested topic count
    let dir = std::env::temp_dir().join("fnomad_engine_api_t_mismatch");
    let ckpt = dir.join("model.ckpt");
    let _ = std::fs::remove_dir_all(&dir);

    train(&tiny(RuntimeKind::Serial).iters(1).checkpoint(ckpt.clone())).unwrap();
    let err = train(
        &tiny(RuntimeKind::Serial).topics(16).iters(1).checkpoint(ckpt.clone()).resume(true),
    )
    .unwrap_err();
    assert!(err.contains("T=8"), "error must name the checkpoint T: {err}");
    assert!(err.contains("T=16"), "error must name the requested T: {err}");
    // the matching topic count still resumes
    train(&tiny(RuntimeKind::Serial).iters(1).checkpoint(ckpt.clone()).resume(true)).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Replay the resume scenario twice end to end and require *bit-identical*
/// observations: LL trajectories, checkpoint bytes, and final
/// assignments.  This is the observation-equivalence gate for the
/// flat-CSR layout — any layout or IO change that perturbs RNG streams,
/// sampling order, or the FNLDA001 byte format shows up here as a hard
/// inequality.  The second leg resumes onto the virtual-time nomad
/// runtime (deterministic by construction; the threaded runtime's token
/// interleaving is scheduler-dependent, so it is covered by the LL-parity
/// tests instead).
#[test]
fn replayed_resume_scenario_is_bit_identical() {
    let run = |tag: &str| {
        let dir = std::env::temp_dir().join(format!("fnomad_engine_api_replay_{tag}"));
        let ckpt = dir.join("model.ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        let first =
            train(&tiny(RuntimeKind::Serial).iters(2).checkpoint(ckpt.clone())).unwrap();
        let bytes = std::fs::read(&ckpt).unwrap();
        let second = train(
            &tiny(RuntimeKind::NomadSim).iters(2).checkpoint(ckpt.clone()).resume(true),
        )
        .unwrap();
        let lls: Vec<f64> = first
            .ll_vs_iter
            .points
            .iter()
            .chain(second.ll_vs_iter.points.iter())
            .map(|&(_, y)| y)
            .collect();
        let _ = std::fs::remove_dir_all(&dir);
        (lls, bytes, second.final_state.z)
    };
    let (ll_a, bytes_a, z_a) = run("a");
    let (ll_b, bytes_b, z_b) = run("b");
    assert_eq!(ll_a, ll_b, "LL trajectory not replayable bit-for-bit");
    assert_eq!(bytes_a, bytes_b, "checkpoint bytes not replayable");
    assert_eq!(z_a, z_b, "final assignments not replayable");
}

#[test]
fn resume_works_on_a_distributed_runtime() {
    // the from_state path: a checkpoint taken under one runtime seeds
    // another (serial -> threaded nomad), and the state stays consistent
    let dir = std::env::temp_dir().join("fnomad_engine_api_resume_nomad");
    let ckpt = dir.join("model.ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let corpus = preset("tiny").unwrap();

    let first = train(&tiny(RuntimeKind::Serial).iters(2).checkpoint(ckpt.clone())).unwrap();
    let first_final_ll = first.ll_vs_iter.last_y().unwrap();

    let resume_cfg = tiny(RuntimeKind::Nomad).iters(2).checkpoint(ckpt.clone()).resume(true);
    let second = train(&resume_cfg).unwrap();
    assert_eq!(second.ll_vs_iter.points[0].1, first_final_ll);
    second.final_state.check_consistency(&corpus).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn save_every_writes_intermediate_checkpoints() {
    let dir = std::env::temp_dir().join("fnomad_engine_api_save_every");
    let ckpt = dir.join("model.ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let corpus = preset("tiny").unwrap();

    /// Watches checkpoint mtimes from inside the run.
    struct CkptWatcher {
        path: std::path::PathBuf,
        seen: usize,
    }
    impl TrainObserver for CkptWatcher {
        fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
            // the driver runs the stock Checkpointer before extra
            // observers, so an epoch-2 save is visible here at epoch 2
            if point.epoch == 2 {
                assert!(self.path.exists(), "no checkpoint after epoch 2");
                self.seen += 1;
            }
            Ok(())
        }
    }

    let mut watcher = CkptWatcher { path: ckpt.clone(), seen: 0 };
    let cfg = tiny(RuntimeKind::Serial).iters(4).checkpoint(ckpt.clone()).save_every(2);
    train_with(&cfg, &mut [&mut watcher as &mut dyn TrainObserver]).unwrap();
    assert_eq!(watcher.seen, 1);
    let state = fnomad_lda::lda::checkpoint::load(&ckpt, &corpus).unwrap();
    state.check_consistency(&corpus).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
