//! Model serving over loopback TCP: the `export-model` → `serve-model` →
//! `infer --remote` pipeline must round-trip over real sockets (both
//! in-process and through the actual CLI binaries), malformed frames must
//! be named errors rather than hangs or panics, a fixed seed must return
//! identical θ̂ across runs — the artifact determinism promise — and the
//! batching/caching/hot-swap core must hold up under concurrent load:
//! 16 hammering clients drop nothing, and a mid-traffic `ReloadModel`
//! never produces a failed or version-mixed response.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use fnomad_lda::corpus::preset;
use fnomad_lda::infer::wire::{MAX_QUERY_FRAME, QUERY_MAGIC};
use fnomad_lda::infer::{
    query_one, serve_model, Client, ModelHost, ModelSlot, Request, Response, ServeConfig,
    StatsReport, TopicModel,
};
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{FLdaWord, Sweep};
use fnomad_lda::util::codec::write_len_prefixed;
use fnomad_lda::util::rng::Pcg32;

fn trained_model_seeded(seed: u64) -> TopicModel {
    let corpus = preset("tiny").unwrap();
    let mut rng = Pcg32::seeded(seed);
    let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
    let mut sweeper = FLdaWord::new(&state, &corpus);
    for _ in 0..8 {
        sweeper.sweep(&mut state, &corpus, &mut rng);
    }
    TopicModel::from_state(&state, Vec::new())
}

fn trained_model() -> TopicModel {
    trained_model_seeded(77)
}

/// Bind a loopback `serve-model` on a free port, serving one connection
/// on a background thread with the given config.
fn spawn_server_once(
    model: TopicModel,
    cfg: ServeConfig,
) -> (String, thread::JoinHandle<Result<(), String>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let slot = Arc::new(ModelSlot::new(ModelHost::new(model), "test@once".into()));
    let handle =
        thread::spawn(move || serve_model(listener, slot, &cfg.once(true).quiet(true)));
    (addr, handle)
}

fn spawn_loopback_server(
    model: TopicModel,
) -> (String, thread::JoinHandle<Result<(), String>>) {
    spawn_server_once(model, ServeConfig::default().threads(1).workers(1))
}

/// A long-lived multi-connection server; its threads are leaked (they die
/// with the test process), which is exactly how the real daemon runs.
fn spawn_multi_server(model: TopicModel, cfg: ServeConfig) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let slot = Arc::new(ModelSlot::new(ModelHost::new(model), "test@multi".into()));
    thread::spawn(move || serve_model(listener, slot, &cfg.quiet(true)));
    addr
}

fn stats_of(addr: &str) -> StatsReport {
    match query_one(addr, &Request::Stats).unwrap() {
        Response::Stats(s) => s,
        other => panic!("expected Stats, got {other:?}"),
    }
}

/// The acceptance scenario, in-process: one connection carries a
/// ModelInfo, an InferDoc and a TopWords query over real TCP, and every
/// answer is well-formed.
#[test]
fn query_round_trip_over_real_tcp() {
    let model = trained_model();
    let t = model.num_topics();
    let (addr, server) = spawn_loopback_server(model);
    let mut client = Client::connect(&addr).unwrap();

    match client.query(&Request::ModelInfo).unwrap() {
        Response::ModelInfo { topics, vocab, total_tokens, has_vocab, model_version, .. } => {
            assert_eq!(topics as usize, t);
            assert_eq!(vocab, 300);
            assert!(total_tokens > 0);
            assert!(!has_vocab);
            assert_eq!(model_version, 1, "the initially loaded model is version 1");
        }
        other => panic!("wrong ModelInfo answer: {other:?}"),
    }

    let req = Request::InferTokens { tokens: vec![0, 1, 2, 3, 4, 5, 6, 7], sweeps: 10, seed: 3 };
    let theta_a = match client.query(&req).unwrap() {
        Response::Theta { theta, used_tokens, model_version } => {
            assert_eq!(used_tokens, 8);
            assert_eq!(theta.len(), t);
            assert_eq!(model_version, 1);
            let sum: f64 = theta.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "theta sums to {sum}");
            theta
        }
        other => panic!("wrong InferTokens answer: {other:?}"),
    };
    // same seed, same answer: the server's inference is deterministic
    match client.query(&req).unwrap() {
        Response::Theta { theta, .. } => assert_eq!(theta, theta_a),
        other => panic!("wrong repeat answer: {other:?}"),
    }

    match client.query(&Request::TopWords { k: 5 }).unwrap() {
        Response::TopWords { topics } => {
            assert_eq!(topics.len(), t);
            for row in &topics {
                assert!(row.len() <= 5);
                for pair in row.windows(2) {
                    assert!(pair[0].count >= pair[1].count);
                }
            }
        }
        other => panic!("wrong TopWords answer: {other:?}"),
    }

    drop(client);
    server.join().unwrap().unwrap();
}

/// A malformed request *body* must come back as a named `Err` response —
/// and the session must survive it (the framing layer is still intact).
#[test]
fn malformed_body_is_a_named_error_and_session_survives() {
    let model = trained_model();
    let t = model.num_topics();
    let (addr, server) = spawn_loopback_server(model);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // a well-framed but garbage body
    write_len_prefixed(&mut writer, b"not a query", MAX_QUERY_FRAME).unwrap();
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Err(e) => {
            assert!(e.contains("bad request"), "unhelpful rejection: {e}");
        }
        other => panic!("expected Err response, got {other:?}"),
    }

    // the same connection still answers real queries
    let good = fnomad_lda::infer::wire::encode_request(&Request::InferTokens {
        tokens: vec![0, 1],
        sweeps: 2,
        seed: 0,
    });
    write_len_prefixed(&mut writer, &good, MAX_QUERY_FRAME).unwrap();
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Theta { theta, .. } => assert_eq!(theta.len(), t),
        other => panic!("session did not survive the bad frame: {other:?}"),
    }

    drop(writer);
    drop(reader);
    server.join().unwrap().unwrap();
}

/// An un-upgraded v1 client must get a *decodable* rejection naming both
/// protocol versions — the frozen `Err` frame layout is what makes the
/// negotiation legible across the skew.
#[test]
fn v1_client_gets_a_named_unsupported_version_error() {
    let (addr, server) = spawn_loopback_server(trained_model());
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // a hand-built v1 ModelInfo frame, exactly as the old client sent it
    let mut body = Vec::new();
    body.extend_from_slice(&QUERY_MAGIC.to_le_bytes());
    body.extend_from_slice(&1u32.to_le_bytes());
    body.push(1); // REQ_MODEL_INFO
    write_len_prefixed(&mut writer, &body, MAX_QUERY_FRAME).unwrap();
    let resp = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&resp).unwrap() {
        Response::Err(e) => {
            assert!(e.contains("unsupported"), "unhelpful rejection: {e}");
            assert!(e.contains("v1") && e.contains("v2"), "must name both versions: {e}");
        }
        other => panic!("expected Err response, got {other:?}"),
    }

    // body-level rejection: the session survives and the server exits clean
    drop(writer);
    drop(reader);
    server.join().unwrap().unwrap();
}

/// A broken *frame* layer (absurd length prefix) is fatal for the
/// session: the server names the fault and drops the connection instead
/// of trying to resync a desynchronized stream.
#[test]
fn oversized_length_prefix_drops_the_session_with_a_named_error() {
    let (addr, server) = spawn_loopback_server(trained_model());
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    stream.flush().unwrap();
    // best-effort Err response before the drop
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Err(e) => assert!(e.contains("cap"), "unhelpful frame error: {e}"),
        other => panic!("expected Err response, got {other:?}"),
    }
    // the connection is closed afterwards
    let mut probe = [0u8; 1];
    assert_eq!(reader.read(&mut probe).unwrap(), 0, "server kept a broken stream open");
    // a --once session error is the server's error (exit-code parity)
    let err = server.join().unwrap().unwrap_err();
    assert!(err.contains("cap"), "server error must name the fault: {err}");
}

/// A client that connects and goes silent is cut off by the configured
/// read deadline with a *named* timeout error — distinguishable from the
/// orderly EOF of a client that simply closed.
#[test]
fn silent_client_is_cut_off_with_a_named_deadline_error() {
    let (addr, server) = spawn_server_once(
        trained_model(),
        ServeConfig::default()
            .threads(1)
            .workers(1)
            .read_deadline(Duration::from_millis(200)),
    );
    let _held_open = TcpStream::connect(&addr).unwrap();
    let err = server.join().unwrap().unwrap_err();
    assert!(err.contains("read deadline exceeded"), "unhelpful timeout error: {err}");

    // an orderly immediate close is the normal end of session, not an error
    let (addr, server) = spawn_loopback_server(trained_model());
    drop(TcpStream::connect(&addr).unwrap());
    server.join().unwrap().unwrap();
}

/// 16 concurrent clients hammer the server with mixed traffic: nothing
/// drops, nothing errors, the answer cache earns hits on the shared hot
/// document, and the Stats counters are sane and monotone.
#[test]
fn sixteen_concurrent_clients_hammer_without_drops() {
    const CLIENTS: u64 = 16;
    const REQUESTS: u64 = 24;
    let addr = spawn_multi_server(
        trained_model(),
        ServeConfig::default().threads(8).workers(3),
    );
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let mut client = Client::connect(&addr)?;
            for j in 0..REQUESTS {
                let resp = match j % 4 {
                    // the shared hot document: identical across all clients
                    0 => client.query(&Request::InferTokens {
                        tokens: vec![0, 1, 2, 3, 4, 5],
                        sweeps: 4,
                        seed: 9,
                    })?,
                    // unique work so the batch queue sees real traffic
                    1 => client.query(&Request::InferTokens {
                        tokens: vec![(c % 7) as u32, (j % 11) as u32, 42],
                        sweeps: 3,
                        seed: c * 31 + j,
                    })?,
                    2 => client.query(&Request::TopWords { k: 5 })?,
                    _ => client.query(&Request::Stats)?,
                };
                match (j % 4, resp) {
                    (0 | 1, Response::Theta { theta, .. }) => {
                        if theta.is_empty() {
                            return Err(format!("client {c} req {j}: empty theta"));
                        }
                    }
                    (2, Response::TopWords { .. }) | (3, Response::Stats(_)) => {}
                    (_, other) => {
                        return Err(format!("client {c} req {j}: wrong answer {other:?}"))
                    }
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().unwrap().unwrap();
    }
    let s1 = stats_of(&addr);
    assert!(
        s1.total_requests >= CLIENTS * REQUESTS,
        "dropped requests: {} < {}",
        s1.total_requests,
        CLIENTS * REQUESTS
    );
    assert_eq!(s1.errors, 0, "hammer produced server-side errors");
    assert!(s1.qps > 0.0);
    assert!(s1.cache_hits > 0, "the shared hot document never hit the cache");
    assert!(s1.infer_requests >= CLIENTS * REQUESTS / 2);
    assert!(s1.p50_us > 0.0);
    assert!(s1.p50_us <= s1.p95_us && s1.p95_us <= s1.p99_us);
    assert!(s1.batches > 0 && s1.batched_docs > 0);
    // the request counter is monotone: asking again counts the ask
    let s2 = stats_of(&addr);
    assert!(s2.total_requests > s1.total_requests);
}

/// Atomic hot-swap under load: 8 clients hammer inference while the
/// model is reloaded mid-traffic.  Zero requests fail, every θ̂ is
/// labeled with exactly one of the two versions, fresh traffic converges
/// to the new version, and Stats records the swap.
#[test]
fn hot_swap_under_load_never_mixes_or_drops() {
    let model_a = trained_model();
    let model_b = trained_model_seeded(123);
    assert_ne!(model_a.fingerprint(), model_b.fingerprint());
    let dir = std::env::temp_dir().join("fnomad_serving_tests");
    let next_path = dir.join("hotswap_next.fnmodel");
    model_b.save(&next_path).unwrap();

    let addr = spawn_multi_server(model_a, ServeConfig::default().threads(8).workers(2));
    match query_one(&addr, &Request::ModelInfo).unwrap() {
        Response::ModelInfo { model_version, .. } => assert_eq!(model_version, 1),
        other => panic!("wrong pre-swap info: {other:?}"),
    }

    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for c in 0..8u64 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        handles.push(thread::spawn(move || -> Result<Vec<u64>, String> {
            let mut client = Client::connect(&addr)?;
            let mut versions = Vec::new();
            let mut j = 0u64;
            while !stop.load(Ordering::Relaxed) {
                j += 1;
                let req = Request::InferTokens {
                    tokens: vec![(c % 13) as u32, (j % 17) as u32 + 13, 7],
                    sweeps: 2,
                    seed: c * 100_000 + j,
                };
                match client.query(&req)? {
                    Response::Theta { model_version, .. } => versions.push(model_version),
                    other => return Err(format!("hammer client {c} got {other:?}")),
                }
            }
            Ok(versions)
        }));
    }

    thread::sleep(Duration::from_millis(100));
    let reload = Request::ReloadModel { path: next_path.to_str().unwrap().into() };
    match query_one(&addr, &reload).unwrap() {
        Response::Reloaded { model_version, model_id, topics, .. } => {
            assert_eq!(model_version, 2);
            assert!(model_id.starts_with("hotswap_next@"), "odd id: {model_id}");
            assert_eq!(topics, 8);
        }
        other => panic!("reload failed: {other:?}"),
    }
    thread::sleep(Duration::from_millis(150));
    stop.store(true, Ordering::Relaxed);

    let mut versions = Vec::new();
    for h in handles {
        versions.extend(h.join().unwrap().unwrap());
    }
    assert!(!versions.is_empty(), "the hammer never got an answer");
    assert!(
        versions.iter().all(|&v| v == 1 || v == 2),
        "a response carried an unknown model version: {versions:?}"
    );

    // convergence: workers re-lease after at most one stale batch plus an
    // idle poll tick, so fresh traffic soon answers from version 2
    let mut converged = false;
    for probe in 0..100u64 {
        let req = Request::InferTokens {
            tokens: vec![2, 4, 6],
            sweeps: 2,
            seed: 999_000 + probe,
        };
        match query_one(&addr, &req).unwrap() {
            Response::Theta { model_version: 2, .. } => {
                converged = true;
                break;
            }
            Response::Theta { .. } => thread::sleep(Duration::from_millis(50)),
            other => panic!("post-swap probe got {other:?}"),
        }
    }
    assert!(converged, "traffic never converged to the swapped-in model");

    let s = stats_of(&addr);
    assert_eq!(s.model_swaps, 1);
    assert_eq!(s.model_version, 2);
    assert_eq!(s.errors, 0, "the swap produced failed responses");
    let _ = std::fs::remove_file(&next_path);
}

/// `.fnmodel` artifact determinism at the file level: export → load gives
/// back a byte-identical artifact and identical inference.
#[test]
fn artifact_roundtrip_preserves_inference() {
    let model = trained_model();
    let path = std::env::temp_dir().join("fnomad_serving_tests").join("rt.fnmodel");
    model.save(&path).unwrap();
    let back = TopicModel::load(&path).unwrap();
    assert_eq!(back.encode(), model.encode());
    let host_a = ModelHost::new(model);
    let host_b = ModelHost::new(back);
    let req = Request::InferTokens { tokens: vec![5, 5, 9, 200], sweeps: 8, seed: 42 };
    match (host_a.answer(req.clone()), host_b.answer(req)) {
        (Response::Theta { theta: a, .. }, Response::Theta { theta: b, .. }) => {
            assert_eq!(a, b)
        }
        other => panic!("expected two Theta answers, got {other:?}"),
    }
    let _ = std::fs::remove_file(path);
}

/// The full pipeline through the real CLI binaries: train 2 epochs with a
/// checkpoint, `export-model`, host it with `serve-model`, query it with
/// `infer --remote`, and grep a well-formed θ̂ response.
#[test]
fn two_process_serving_pipeline_via_cli() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    let dir = std::env::temp_dir().join("fnomad_serving_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("cli.ckpt");
    let fnmodel = dir.join("cli.fnmodel");
    let _ = std::fs::remove_file(&ckpt);

    let run = |args: &[&str]| {
        let out = Command::new(bin).args(args).output().expect("spawn fnomad-lda");
        assert!(
            out.status.success(),
            "{args:?} failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&[
        "train", "--preset", "tiny", "--topics", "8", "--iters", "2", "--eval", "rust",
        "--quiet", "--checkpoint", ckpt.to_str().unwrap(),
    ]);
    let exported = run(&[
        "export-model", "--checkpoint", ckpt.to_str().unwrap(), "--preset", "tiny", "--out",
        fnmodel.to_str().unwrap(),
    ]);
    assert!(exported.contains("exported"), "no export summary: {exported}");

    let mut server = Command::new(bin)
        .args(["serve-model", "--model", fnmodel.to_str().unwrap()])
        .args(["--listen", "127.0.0.1:0", "--once", "--quiet"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve-model");
    let mut banner = String::new();
    BufReader::new(server.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-model banner: {banner:?}"));

    // a held-out document the training corpus never saw in this order
    let infer_out = run(&[
        "infer", "--remote", addr, "--tokens", "0,1,2,3,4,5,6,7", "--sweeps", "10", "--top",
        "3", "--seed", "5",
    ]);
    assert!(infer_out.contains("theta_top:"), "no theta line: {infer_out}");
    let theta_line = infer_out.lines().find(|l| l.starts_with("theta_top:")).unwrap();
    // well-formed: `topic:mass` pairs with masses in (0, 1)
    let pairs: Vec<&str> = theta_line.trim_start_matches("theta_top:").split_whitespace().collect();
    assert_eq!(pairs.len(), 3, "expected 3 top topics: {theta_line}");
    for pair in &pairs {
        let (topic, mass) = pair.split_once(':').expect("topic:mass pair");
        let topic: usize = topic.parse().expect("topic id");
        assert!(topic < 8);
        let mass: f64 = mass.parse().expect("theta mass");
        assert!(mass > 0.0 && mass < 1.0, "bad mass in {theta_line}");
    }
    let status = server.wait().expect("serve-model exit");
    assert!(status.success(), "serve-model failed: {status}");

    // local inference from the artifact is deterministic across process runs
    let local = &[
        "infer", "--model", fnmodel.to_str().unwrap(), "--tokens", "0,1,2,3,4,5,6,7",
        "--sweeps", "10", "--top", "3", "--seed", "5",
    ];
    let a = run(local.as_slice());
    let b = run(local.as_slice());
    assert_eq!(a, b, "fixed-seed CLI inference diverged across runs");
    // and the remote answer matches the local one: same artifact, same
    // seed, same engine on both sides of the socket (the version label
    // lives off the theta_top line for exactly this comparison)
    assert_eq!(
        a.lines().find(|l| l.starts_with("theta_top:")),
        Some(theta_line),
        "remote and local θ̂ diverged"
    );

    // model info renders from the artifact
    let info = run(&["infer", "--model", fnmodel.to_str().unwrap(), "--info"]);
    assert!(info.contains("T=8"), "bad info line: {info}");
    assert!(info.contains("version=0"), "local info must carry version 0: {info}");

    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_file(&fnmodel);
}
