//! Model serving over loopback TCP: the `export-model` → `serve-model` →
//! `infer --remote` pipeline must round-trip over real sockets (both
//! in-process and through the actual CLI binaries), malformed frames must
//! be named errors rather than hangs or panics, and a fixed seed must
//! return identical θ̂ across runs — the artifact determinism promise.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::thread;

use fnomad_lda::corpus::preset;
use fnomad_lda::infer::wire::MAX_QUERY_FRAME;
use fnomad_lda::infer::{
    serve_model, Client, ModelHost, Request, Response, ServeModelOpts, TopicModel,
};
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{FLdaWord, Sweep};
use fnomad_lda::util::codec::write_len_prefixed;
use fnomad_lda::util::rng::Pcg32;

fn trained_model() -> TopicModel {
    let corpus = preset("tiny").unwrap();
    let mut rng = Pcg32::seeded(77);
    let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
    let mut sweeper = FLdaWord::new(&state, &corpus);
    for _ in 0..8 {
        sweeper.sweep(&mut state, &corpus, &mut rng);
    }
    TopicModel::from_state(&state, Vec::new())
}

/// Bind a loopback `serve-model` on a free port, serving one connection
/// on a background thread.
fn spawn_loopback_server(
    model: TopicModel,
) -> (String, thread::JoinHandle<Result<(), String>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let host = Arc::new(ModelHost::new(model));
    let handle = thread::spawn(move || {
        serve_model(listener, host, &ServeModelOpts { threads: 1, once: true, quiet: true })
    });
    (addr, handle)
}

/// The acceptance scenario, in-process: one connection carries a
/// ModelInfo, an InferDoc and a TopWords query over real TCP, and every
/// answer is well-formed.
#[test]
fn query_round_trip_over_real_tcp() {
    let model = trained_model();
    let t = model.num_topics();
    let (addr, server) = spawn_loopback_server(model);
    let mut client = Client::connect(&addr).unwrap();

    match client.query(&Request::ModelInfo).unwrap() {
        Response::ModelInfo { topics, vocab, total_tokens, has_vocab, .. } => {
            assert_eq!(topics as usize, t);
            assert_eq!(vocab, 300);
            assert!(total_tokens > 0);
            assert!(!has_vocab);
        }
        other => panic!("wrong ModelInfo answer: {other:?}"),
    }

    let req = Request::InferTokens { tokens: vec![0, 1, 2, 3, 4, 5, 6, 7], sweeps: 10, seed: 3 };
    let theta_a = match client.query(&req).unwrap() {
        Response::Theta { theta, used_tokens } => {
            assert_eq!(used_tokens, 8);
            assert_eq!(theta.len(), t);
            let sum: f64 = theta.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "theta sums to {sum}");
            theta
        }
        other => panic!("wrong InferTokens answer: {other:?}"),
    };
    // same seed, same answer: the server's inference is deterministic
    match client.query(&req).unwrap() {
        Response::Theta { theta, .. } => assert_eq!(theta, theta_a),
        other => panic!("wrong repeat answer: {other:?}"),
    }

    match client.query(&Request::TopWords { k: 5 }).unwrap() {
        Response::TopWords { topics } => {
            assert_eq!(topics.len(), t);
            for row in &topics {
                assert!(row.len() <= 5);
                for pair in row.windows(2) {
                    assert!(pair[0].count >= pair[1].count);
                }
            }
        }
        other => panic!("wrong TopWords answer: {other:?}"),
    }

    drop(client);
    server.join().unwrap().unwrap();
}

/// A malformed request *body* must come back as a named `Err` response —
/// and the session must survive it (the framing layer is still intact).
#[test]
fn malformed_body_is_a_named_error_and_session_survives() {
    let model = trained_model();
    let t = model.num_topics();
    let (addr, server) = spawn_loopback_server(model);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // a well-framed but garbage body
    write_len_prefixed(&mut writer, b"not a query", MAX_QUERY_FRAME).unwrap();
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Err(e) => {
            assert!(e.contains("bad request"), "unhelpful rejection: {e}");
        }
        other => panic!("expected Err response, got {other:?}"),
    }

    // the same connection still answers real queries
    let good = fnomad_lda::infer::wire::encode_request(&Request::InferTokens {
        tokens: vec![0, 1],
        sweeps: 2,
        seed: 0,
    });
    write_len_prefixed(&mut writer, &good, MAX_QUERY_FRAME).unwrap();
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Theta { theta, .. } => assert_eq!(theta.len(), t),
        other => panic!("session did not survive the bad frame: {other:?}"),
    }

    drop(writer);
    drop(reader);
    server.join().unwrap().unwrap();
}

/// A broken *frame* layer (absurd length prefix) is fatal for the
/// session: the server names the fault and drops the connection instead
/// of trying to resync a desynchronized stream.
#[test]
fn oversized_length_prefix_drops_the_session_with_a_named_error() {
    let (addr, server) = spawn_loopback_server(trained_model());
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    stream.flush().unwrap();
    // best-effort Err response before the drop
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let body = fnomad_lda::util::codec::read_len_prefixed(&mut reader, MAX_QUERY_FRAME).unwrap();
    match fnomad_lda::infer::wire::decode_response(&body).unwrap() {
        Response::Err(e) => assert!(e.contains("cap"), "unhelpful frame error: {e}"),
        other => panic!("expected Err response, got {other:?}"),
    }
    // the connection is closed afterwards
    let mut probe = [0u8; 1];
    assert_eq!(reader.read(&mut probe).unwrap(), 0, "server kept a broken stream open");
    // a --once session error is the server's error (exit-code parity)
    let err = server.join().unwrap().unwrap_err();
    assert!(err.contains("cap"), "server error must name the fault: {err}");
}

/// `.fnmodel` artifact determinism at the file level: export → load gives
/// back a byte-identical artifact and identical inference.
#[test]
fn artifact_roundtrip_preserves_inference() {
    let model = trained_model();
    let path = std::env::temp_dir().join("fnomad_serving_tests").join("rt.fnmodel");
    model.save(&path).unwrap();
    let back = TopicModel::load(&path).unwrap();
    assert_eq!(back.encode(), model.encode());
    let host_a = ModelHost::new(model);
    let host_b = ModelHost::new(back);
    let req = Request::InferTokens { tokens: vec![5, 5, 9, 200], sweeps: 8, seed: 42 };
    match (host_a.answer(req.clone()), host_b.answer(req)) {
        (Response::Theta { theta: a, .. }, Response::Theta { theta: b, .. }) => {
            assert_eq!(a, b)
        }
        other => panic!("expected two Theta answers, got {other:?}"),
    }
    let _ = std::fs::remove_file(path);
}

/// The full pipeline through the real CLI binaries: train 2 epochs with a
/// checkpoint, `export-model`, host it with `serve-model`, query it with
/// `infer --remote`, and grep a well-formed θ̂ response.
#[test]
fn two_process_serving_pipeline_via_cli() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    let dir = std::env::temp_dir().join("fnomad_serving_cli");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("cli.ckpt");
    let fnmodel = dir.join("cli.fnmodel");
    let _ = std::fs::remove_file(&ckpt);

    let run = |args: &[&str]| {
        let out = Command::new(bin).args(args).output().expect("spawn fnomad-lda");
        assert!(
            out.status.success(),
            "{args:?} failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&[
        "train", "--preset", "tiny", "--topics", "8", "--iters", "2", "--eval", "rust",
        "--quiet", "--checkpoint", ckpt.to_str().unwrap(),
    ]);
    let exported = run(&[
        "export-model", "--checkpoint", ckpt.to_str().unwrap(), "--preset", "tiny", "--out",
        fnmodel.to_str().unwrap(),
    ]);
    assert!(exported.contains("exported"), "no export summary: {exported}");

    let mut server = Command::new(bin)
        .args(["serve-model", "--model", fnmodel.to_str().unwrap()])
        .args(["--listen", "127.0.0.1:0", "--once", "--quiet"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve-model");
    let mut banner = String::new();
    BufReader::new(server.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-model banner: {banner:?}"));

    // a held-out document the training corpus never saw in this order
    let infer_out = run(&[
        "infer", "--remote", addr, "--tokens", "0,1,2,3,4,5,6,7", "--sweeps", "10", "--top",
        "3", "--seed", "5",
    ]);
    assert!(infer_out.contains("theta_top:"), "no theta line: {infer_out}");
    let theta_line = infer_out.lines().find(|l| l.starts_with("theta_top:")).unwrap();
    // well-formed: `topic:mass` pairs with masses in (0, 1)
    let pairs: Vec<&str> = theta_line.trim_start_matches("theta_top:").split_whitespace().collect();
    assert_eq!(pairs.len(), 3, "expected 3 top topics: {theta_line}");
    for pair in &pairs {
        let (topic, mass) = pair.split_once(':').expect("topic:mass pair");
        let topic: usize = topic.parse().expect("topic id");
        assert!(topic < 8);
        let mass: f64 = mass.parse().expect("theta mass");
        assert!(mass > 0.0 && mass < 1.0, "bad mass in {theta_line}");
    }
    let status = server.wait().expect("serve-model exit");
    assert!(status.success(), "serve-model failed: {status}");

    // local inference from the artifact is deterministic across process runs
    let local = &[
        "infer", "--model", fnmodel.to_str().unwrap(), "--tokens", "0,1,2,3,4,5,6,7",
        "--sweeps", "10", "--top", "3", "--seed", "5",
    ];
    let a = run(local.as_slice());
    let b = run(local.as_slice());
    assert_eq!(a, b, "fixed-seed CLI inference diverged across runs");
    // and the remote answer matches the local one: same artifact, same
    // seed, same engine on both sides of the socket
    assert_eq!(
        a.lines().find(|l| l.starts_with("theta_top:")),
        Some(theta_line),
        "remote and local θ̂ diverged"
    );

    // model info renders from the artifact
    let info = run(&["infer", "--model", fnmodel.to_str().unwrap(), "--info"]);
    assert!(info.contains("T=8"), "bad info line: {info}");

    let _ = std::fs::remove_file(&ckpt);
    let _ = std::fs::remove_file(&fnmodel);
}
