//! Cross-module integration tests: sampler exactness against the dense
//! conditional, runtime-vs-runtime convergence parity, and corpus→state
//! plumbing at a non-trivial scale.

use fnomad_lda::corpus::presets::preset;
use fnomad_lda::corpus::synthetic::{generate, SyntheticSpec};
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{self, log_likelihood, Sweep};
use fnomad_lda::nomad::{NomadConfig, NomadRuntime};
use fnomad_lda::ps::{PsConfig, PsRuntime};
use fnomad_lda::util::rng::Pcg32;

fn mid_corpus() -> fnomad_lda::corpus::Corpus {
    generate(&SyntheticSpec {
        name: "mid".into(),
        num_docs: 400,
        vocab: 900,
        avg_doc_len: 60.0,
        true_topics: 12,
        seed: 77,
        ..Default::default()
    })
}

/// Single-site exactness: freeze the state, repeatedly resample ONE token
/// with each exact sampler, and compare the empirical distribution with
/// the dense conditional of eq. (2).  This is the strongest correctness
/// statement about the q/r decompositions + F+tree plumbing.
#[test]
fn exact_samplers_match_dense_conditional_at_single_site() {
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(16);
    let mut rng = Pcg32::seeded(0x5175);
    let state0 = LdaState::init_random(&corpus, hyper, &mut rng);

    // target: conditional for token (doc 0, pos 0) with itself removed;
    // under the flat CSR layout that token is z[0]
    let doc = 0usize;
    let word = corpus.doc(0)[0] as usize;
    let mut removed = state0.clone();
    let old = removed.z[0];
    removed.ntd[doc].dec(old);
    removed.nwt[word].dec(old);
    removed.nt[old as usize] -= 1;
    let p = removed.dense_conditional(doc, word);
    let total: f64 = p.iter().sum();

    for name in ["plain", "sparse", "flda-doc", "flda-word"] {
        // resample via full sweeps on a corpus where ONLY doc0 exists —
        // impractical; instead exploit sweep determinism: run many sweeps
        // from the same frozen state with different rng streams and look
        // at the distribution of the first token's new assignment.
        let draws = 4000;
        let mut counts = vec![0usize; hyper.t];
        for seed in 0..draws {
            let mut rng = Pcg32::new(0xFACE, seed as u64);
            let mut state = state0.clone();
            let mut sampler = lda::by_name(name, &state, &corpus).unwrap();
            sampler.sweep(&mut state, &corpus, &mut rng);
            counts[state.z[0] as usize] += 1;
        }
        // doc-major samplers resample token (0,0) FIRST, so its
        // distribution is exactly the conditional above; flda-word visits
        // it when word w comes up — other tokens of other words sampled
        // before may shift counts, so allow a wider tolerance there.
        let loose = name == "flda-word";
        for t in 0..hyper.t {
            let want = p[t] / total;
            let got = counts[t] as f64 / draws as f64;
            let sigma = (want.max(1e-4) / draws as f64).sqrt();
            let tol = if loose { 8.0 * sigma + 0.01 } else { 5.0 * sigma };
            assert!(
                (got - want).abs() <= tol,
                "{name}: topic {t} empirical {got:.4} vs conditional {want:.4} (tol {tol:.4})"
            );
        }
    }
}

/// All runtimes converge to comparable model quality on a mid-size corpus.
#[test]
fn runtimes_reach_comparable_quality_mid_scale() {
    let corpus = mid_corpus();
    let hyper = Hyper::paper_default(32);
    let iters = 8;

    // serial reference
    let serial = {
        let mut rng = Pcg32::seeded(1);
        let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
        let mut sampler = lda::FLdaWord::new(&state, &corpus);
        for _ in 0..iters {
            sampler.sweep(&mut state, &corpus, &mut rng);
        }
        state.check_consistency(&corpus).unwrap();
        log_likelihood(&state)
    };

    // threaded nomad
    let nomad = {
        let cfg = NomadConfig { workers: 4, seed: 1, ..Default::default() };
        let mut rt = NomadRuntime::new(&corpus, hyper, cfg);
        for _ in 0..iters {
            rt.run_epoch();
        }
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        let ll = log_likelihood(&state);
        rt.shutdown();
        ll
    };

    // threaded parameter server
    let ps = {
        let mut rt = PsRuntime::new(&corpus, hyper, PsConfig {
            workers: 4,
            seed: 1,
            batch_docs: 8,
        });
        for _ in 0..iters {
            rt.run_epoch();
        }
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        let ll = log_likelihood(&state);
        rt.shutdown();
        ll
    };

    for (name, ll) in [("nomad", nomad), ("ps", ps)] {
        assert!(
            (ll - serial).abs() / serial.abs() < 0.02,
            "{name} LL {ll:.4e} too far from serial {serial:.4e}"
        );
    }
}

/// Nomad determinism: identical config + seed → identical final state.
#[test]
fn nomad_sim_is_deterministic() {
    use fnomad_lda::simnet::nomad_sim::{NomadSim, NomadSimConfig};
    use fnomad_lda::simnet::ClusterSpec;
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(8);
    let run = || {
        let mut cfg = NomadSimConfig::new(ClusterSpec::multicore(4), 8);
        cfg.seed = 3;
        let mut sim = NomadSim::new(&corpus, hyper, cfg);
        sim.run_epoch();
        sim.run_epoch();
        let s = sim.gather_state(&corpus);
        (s.z, sim.vtime_secs())
    };
    let (z1, t1) = run();
    let (z2, t2) = run();
    assert_eq!(z1, z2);
    assert!((t1 - t2).abs() < 1e-12);
}

/// Corpus pipeline -> training on preprocessed real text.
#[test]
fn text_pipeline_to_topics() {
    use fnomad_lda::corpus::text::{build_corpus, PipelineOpts};
    let texts: Vec<String> = (0..40)
        .map(|i| {
            if i % 2 == 0 {
                "the stock market prices rose as investors traded shares and bonds \
                 in the market exchange trading stocks"
                    .to_string()
            } else {
                "the football team scored goals while players passed the ball during \
                 the game and fans cheered the team"
                    .to_string()
            }
        })
        .collect();
    let corpus = build_corpus(
        &texts,
        &PipelineOpts { min_count: 3, min_docs: 3, ..Default::default() },
        "texty",
    );
    corpus.validate().unwrap();
    let hyper = Hyper::paper_default(4);
    let mut rng = Pcg32::seeded(5);
    let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
    let mut sampler = lda::FLdaWord::new(&state, &corpus);
    for _ in 0..30 {
        sampler.sweep(&mut state, &corpus, &mut rng);
    }
    state.check_consistency(&corpus).unwrap();
    // the two ground-truth themes should separate: the top topic of a
    // sports doc differs from the top topic of a finance doc
    let theta_fin = fnomad_lda::lda::topics::theta_row(&state, 0);
    let theta_spo = fnomad_lda::lda::topics::theta_row(&state, 1);
    let argmax = |v: &[f64]| {
        v.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
    };
    assert_ne!(argmax(&theta_fin), argmax(&theta_spo), "themes failed to separate");
}
