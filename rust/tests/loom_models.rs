//! Exhaustive model checks of the crate's hand-rolled concurrency
//! protocols, driven by [loom](https://docs.rs/loom).
//!
//! This target compiles to an empty test binary unless built with
//! `--cfg loom` *and* the loom dependency appended to the manifest (the
//! committed manifest stays dependency-free so the default build is
//! hermetic).  The CI `loom` job — and the one-liner in the
//! `util::sync` module docs — does both:
//!
//! ```sh
//! printf '\n%s\n%s\n' "[target.'cfg(loom)'.dependencies]" 'loom = "0.7"' >> Cargo.toml
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_models
//! ```
//!
//! Under `--cfg loom` the production types themselves are rebuilt on
//! loom's `Mutex`/`Condvar`/atomics via the `util::sync` shim, so what
//! runs here is the real `BatchQueue`/`VersionedSlot`/`OfferQueue` code,
//! not a model of it.  Loom explores every interleaving (bounded by
//! `LOOM_MAX_PREEMPTIONS`), checking the asserts plus deadlock- and
//! leak-freedom on each execution.
//!
//! Model-writing rules imposed by the shim (see `util::sync` docs):
//! timeouts are not modeled — every condvar wait must be satisfied by an
//! eventual notify, so every model guarantees a fulfilling event (a pop,
//! a close, a complete) on some thread.

#![cfg(loom)]

use std::sync::Arc;
use std::time::Duration;

use fnomad_lda::infer::batch::BatchQueue;
use fnomad_lda::infer::server::VersionedSlot;
use fnomad_lda::resilience::writer::OfferQueue;

/// Effectively infinite: deadlines never fire inside a model (loom waits
/// are untimed), so every exit is protocol-driven.
const FOREVER: Duration = Duration::from_secs(3600);

// ------------------------------------------------------------ BatchQueue

/// Producer/consumer transfer: two producers, one consumer, capacity 2.
/// Every pushed job is popped exactly once; per-producer FIFO holds
/// trivially (one job each); nothing deadlocks.
#[test]
fn batch_queue_transfers_every_job_exactly_once() {
    loom::model(|| {
        let q = Arc::new(BatchQueue::new(2));
        let p1 = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.push(1u64, FOREVER).unwrap())
        };
        let p2 = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.push(2u64, FOREVER).unwrap())
        };
        let mut got = Vec::new();
        while got.len() < 2 {
            match q.pop_batch(2, Duration::ZERO, FOREVER) {
                Some(batch) => got.extend(batch),
                None => break,
            }
        }
        p1.join().unwrap();
        p2.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "every accepted job pops exactly once");
    });
}

/// Backpressure + close-drain: capacity 1, a producer that may park on
/// the full queue, a closer racing it, a draining consumer.  The blocked
/// producer must always be woken (by a freed slot or by the close); an
/// accepted job is drained exactly once; a rejected job never appears.
#[test]
fn batch_queue_close_wakes_blocked_producers_and_drains_accepted_work() {
    loom::model(|| {
        let q = Arc::new(BatchQueue::new(1));
        q.push(1u64, FOREVER).unwrap();
        let producer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.push(2u64, FOREVER))
        };
        let closer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || q.close())
        };
        let mut got = Vec::new();
        while let Some(batch) = q.pop_batch(1, Duration::ZERO, FOREVER) {
            got.extend(batch);
        }
        let pushed = producer.join().unwrap();
        closer.join().unwrap();
        match pushed {
            Ok(()) => assert_eq!(got, vec![1, 2], "an accepted push must drain"),
            Err(e) => {
                assert!(e.contains("shutting down"), "unhelpful close error: {e}");
                assert_eq!(got, vec![1], "a rejected push must never be drained");
            }
        }
    });
}

// --------------------------------------------------------- VersionedSlot

/// The version-hint discipline under two concurrent swappers: the hint is
/// monotone, and a reader that observes hint `v` gets a lease with
/// `version >= v` — the hint never runs ahead of the published value.
#[test]
fn versioned_slot_hint_never_leads_the_published_generation() {
    loom::model(|| {
        let slot = Arc::new(VersionedSlot::new(10u32, "g1".into()));
        let s1 = {
            let slot = Arc::clone(&slot);
            loom::thread::spawn(move || slot.swap(20, "g2".into()))
        };
        let s2 = {
            let slot = Arc::clone(&slot);
            loom::thread::spawn(move || slot.swap(30, "g3".into()))
        };
        let h1 = slot.version();
        let lease = slot.load();
        assert!(
            lease.version >= h1,
            "hint {h1} ran ahead of the leased generation {}",
            lease.version
        );
        let h2 = slot.version();
        assert!(h2 >= h1, "the hint must be monotone ({h1} then {h2})");
        s1.join().unwrap();
        s2.join().unwrap();
        assert_eq!(slot.version(), 3);
        assert_eq!(slot.load().version, 3, "the last swap wins the slot");
    });
}

/// The worker lease/re-lease protocol against a concurrent swap: a batch
/// is only ever labeled with the version of an actually-held lease, and
/// once the hint reports a newer generation, re-leasing observes it —
/// which bounds staleness to the single batch drained on the old lease.
#[test]
fn versioned_slot_relabel_after_swap_is_at_most_one_generation_late() {
    loom::model(|| {
        let slot = Arc::new(VersionedSlot::new(0u32, "m1".into()));
        let swapper = {
            let slot = Arc::clone(&slot);
            loom::thread::spawn(move || slot.swap(1, "m2".into()))
        };
        // worker: lease, label one batch, poll the hint, maybe re-lease
        let lease = slot.load();
        let label = lease.version;
        assert!(label == 1 || label == 2, "labels come from real leases");
        if slot.version() != lease.version {
            let release = slot.load();
            assert!(
                release.version > lease.version,
                "a hint change must surface a newer generation"
            );
            assert_eq!(release.value, 1, "the new generation carries the new value");
        }
        swapper.join().unwrap();
    });
}

// ------------------------------------------------------------ OfferQueue

/// The snapshot-sink contract: offer (accepted when the consumer lives
/// and the queue has room) → flush blocks until the consumer processed
/// it → after the consumer exits, flush reports the dead consumer.
#[test]
fn offer_queue_flush_tracks_processing_and_reports_a_dead_consumer() {
    loom::model(|| {
        let q = Arc::new(OfferQueue::new(2));
        let consumer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                while let Some((seq, _item)) = q.pop() {
                    q.complete(seq);
                }
                q.consumer_exited();
            })
        };
        assert!(q.offer(7u32), "room + live consumer must accept");
        assert!(q.flush(), "a live consumer must flush accepted work");
        q.close();
        consumer.join().unwrap();
        assert!(!q.flush(), "flush must report an exited consumer");
    });
}

/// Offer never blocks and never loses accepted work: with capacity 1 and
/// a slow consumer, later offers may be dropped — but whatever was
/// accepted drains in order, exactly once, and drops never appear.
#[test]
fn offer_queue_drops_on_full_but_never_loses_accepted_items() {
    loom::model(|| {
        let q = Arc::new(OfferQueue::new(1));
        let consumer = {
            let q = Arc::clone(&q);
            loom::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((seq, item)) = q.pop() {
                    got.push(item);
                    q.complete(seq);
                }
                q.consumer_exited();
                got
            })
        };
        let a1 = q.offer(1u32);
        let a2 = q.offer(2u32);
        let a3 = q.offer(3u32);
        q.close();
        let got = consumer.join().unwrap();
        let accepted: Vec<u32> = [(1u32, a1), (2, a2), (3, a3)]
            .iter()
            .filter(|(_, a)| *a)
            .map(|(v, _)| *v)
            .collect();
        assert_eq!(
            got, accepted,
            "accepted snapshots drain in order exactly once; drops never appear"
        );
        assert!(a1, "an empty queue with a live consumer must accept");
    });
}
