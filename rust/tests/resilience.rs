//! Resilient training under deterministic fault injection: a worker that
//! panics or a TCP peer that vanishes mid-epoch must cost a restart from
//! the latest valid checkpoint, never the run; a torn checkpoint must be
//! skipped, never loaded; and the async snapshot writer must never stall
//! the epoch loop.

use std::io::{BufRead, BufReader, Read};
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use fnomad_lda::coordinator::{train, EvalPolicy, RuntimeKind, TrainConfig};
use fnomad_lda::corpus::preset;
use fnomad_lda::lda::{Hyper, LdaState};
use fnomad_lda::nomad::net::{serve, ServeOpts};
use fnomad_lda::resilience::{CheckpointWriter, FaultPlan, SnapshotStore};
use fnomad_lda::util::rng::Pcg32;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fnomad_resilience_tests").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn resilient(name: &str, iters: usize) -> TrainConfig {
    TrainConfig::preset("tiny")
        .runtime(RuntimeKind::Nomad)
        .workers(2)
        .topics(8)
        .iters(iters)
        .eval(EvalPolicy::Rust)
        .quiet(true)
        .checkpoint_dir(tmpdir(name))
        .max_restarts(2)
}

/// The headline acceptance scenario, in-process: a local worker panics at
/// epoch 2 of 5 and the run still completes every epoch with an exact,
/// consistent final state and a finite likelihood.
#[test]
fn worker_panic_recovers_and_completes() {
    let cfg = resilient("panic", 5)
        .fault(FaultPlan { panic_worker: Some((1, 2)), ..Default::default() });
    let res = train(&cfg).unwrap();
    let corpus = preset("tiny").unwrap();
    res.final_state.check_consistency(&corpus).unwrap();
    assert_eq!(res.final_state.total_tokens() as usize, corpus.num_tokens());
    assert_eq!(res.ll_vs_iter.points.len(), 6, "evals at epoch 0..=5");
    assert!(res.ll_vs_iter.last_y().unwrap().is_finite());
    let _ = std::fs::remove_dir_all(cfg.checkpoint_dir.unwrap());
}

/// The decoupling contract: `offer` returns immediately even while the
/// store is (artificially) slow, and `flush` is the only call that waits
/// for the disk.
#[test]
fn snapshot_offer_never_blocks_on_disk() {
    let dir = tmpdir("nonblocking");
    let corpus = preset("tiny").unwrap();
    let mut rng = Pcg32::seeded(5);
    let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);

    let delay = Duration::from_millis(300);
    let mut store = SnapshotStore::open(&dir, 2).unwrap();
    store.set_write_delay(delay);
    let writer = CheckpointWriter::spawn(Arc::new(store), true);
    let sink = writer.sink();

    let t0 = Instant::now();
    assert!(sink.offer(1, state.clone()), "empty queue must accept");
    assert!(
        t0.elapsed() < Duration::from_millis(100),
        "offer blocked on the (slow) disk: {:?}",
        t0.elapsed()
    );
    assert!(sink.flush(), "a live writer must acknowledge the flush");
    assert!(
        t0.elapsed() >= delay,
        "flush returned before the write finished: {:?}",
        t0.elapsed()
    );
    writer.finish();
    // the writer thread is gone: flush must say so, not silently no-op
    // (recovery reads this to know queued snapshots were lost)
    assert!(!sink.flush(), "flush must report a dead writer");

    // what landed is the snapshot we offered
    let reopened = SnapshotStore::open(&dir, 2).unwrap();
    let (epoch, loaded) = reopened.load_latest_valid(&corpus, usize::MAX).unwrap();
    assert_eq!(epoch, 1);
    assert_eq!(loaded.z, state.z);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupted newest snapshot fails its fingerprint re-check and the
/// recovery read path falls back to the previous retained epoch.
#[test]
fn corrupt_latest_checkpoint_falls_back_to_previous() {
    let dir = tmpdir("fallback");
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(8);
    let s1 = LdaState::init_random(&corpus, hyper, &mut Pcg32::seeded(1));
    let s2 = LdaState::init_random(&corpus, hyper, &mut Pcg32::seeded(2));
    assert_ne!(s1.z, s2.z, "distinct states are the point of this test");

    let store = SnapshotStore::open(&dir, 3).unwrap();
    store.save(1, &s1).unwrap();
    store.save(2, &s2).unwrap();
    store.corrupt_latest().unwrap();
    let (epoch, loaded) = store.load_latest_valid(&corpus, usize::MAX).unwrap();
    assert_eq!(epoch, 1, "the torn epoch-2 snapshot must be skipped");
    assert_eq!(loaded.z, s1.z);
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end version of the fallback: the ring fails *and* the newest
/// checkpoint is torn; recovery skips it, reloads an older epoch, re-runs
/// the gap, and the run still completes exactly.
#[test]
fn recovery_survives_a_torn_latest_checkpoint() {
    let cfg = resilient("torn", 4).fault(FaultPlan {
        panic_worker: Some((0, 3)),
        corrupt_latest_checkpoint: true,
        ..Default::default()
    });
    let res = train(&cfg).unwrap();
    let corpus = preset("tiny").unwrap();
    res.final_state.check_consistency(&corpus).unwrap();
    assert_eq!(res.final_state.total_tokens() as usize, corpus.num_tokens());
    assert_eq!(res.ll_vs_iter.points.len(), 5);
    let _ = std::fs::remove_dir_all(cfg.checkpoint_dir.unwrap());
}

/// Regression: reusing a `--checkpoint-dir` from a previous run must not
/// resurrect that run's snapshots.  Before `begin_run` + the epoch-bounded
/// reload, run 2's recovery reloaded run 1's highest-epoch snapshot (a
/// different topic count here, to make the leak observable), decided the
/// lost epochs had "already run", and silently completed with the other
/// run's model.
#[test]
fn reused_checkpoint_dir_cannot_resurrect_a_previous_run() {
    let dir = tmpdir("reused-dir");
    let corpus = preset("tiny").unwrap();
    let base = |topics: usize, iters: usize| {
        TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .workers(2)
            .topics(topics)
            .iters(iters)
            .eval(EvalPolicy::Rust)
            .quiet(true)
            .checkpoint_dir(dir.clone())
            .keep(2)
            .max_restarts(2)
    };
    // run 1 fills the store with T=4 snapshots up to epoch 3
    train(&base(4, 3)).unwrap();
    assert!(
        !SnapshotStore::open(&dir, 2).unwrap().entries().is_empty(),
        "run 1 must leave retained snapshots for the reuse scenario"
    );

    // run 2 reuses the directory with T=8 and a worker panic at epoch 2
    let cfg = base(8, 5).fault(FaultPlan { panic_worker: Some((1, 2)), ..Default::default() });
    let res = train(&cfg).unwrap();
    assert_eq!(res.final_state.hyper.t, 8, "recovery resurrected the previous run's model");
    res.final_state.check_consistency(&corpus).unwrap();
    assert_eq!(res.final_state.total_tokens() as usize, corpus.num_tokens());
    assert_eq!(res.ll_vs_iter.points.len(), 6, "every requested epoch must actually run");

    // and the store now holds only run-2 snapshots
    let store = SnapshotStore::open(&dir, 2).unwrap();
    assert!(store.entries().iter().all(|e| e.epoch <= 5));
    let (_, newest) = store.load_latest_valid(&corpus, usize::MAX).unwrap();
    assert_eq!(newest.hyper.t, 8);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A remote TCP slot is force-closed mid-run; the supervisor probes the
/// (still listening) worker, re-splices it, and finishes all epochs.
#[test]
fn dropped_tcp_peer_recovers_in_process() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // non-once host: each session runs on its own thread and the listener
    // keeps accepting, so the respawned ring can reconnect
    thread::spawn(move || {
        let _ = serve(listener, &ServeOpts { quiet: true, ..Default::default() });
    });

    let cfg = resilient("drop-peer", 4)
        .workers(1)
        .remote(vec![addr])
        .fault(FaultPlan { drop_peer: Some((1, 2)), ..Default::default() });
    let res = train(&cfg).unwrap();
    let corpus = preset("tiny").unwrap();
    res.final_state.check_consistency(&corpus).unwrap();
    assert_eq!(res.final_state.total_tokens() as usize, corpus.num_tokens());
    let _ = std::fs::remove_dir_all(cfg.checkpoint_dir.unwrap());
}

/// Two real processes through the CLI: `serve-worker --fail-after-epochs`
/// kills itself mid-epoch (exit 9, no clean teardown) and the training
/// process must log the recovery line and still succeed.
#[test]
fn two_process_fail_after_epochs_recovers_via_cli() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    let mut worker = Command::new(bin)
        .args(["serve-worker", "--listen", "127.0.0.1:0", "--once", "--quiet"])
        .args(["--fail-after-epochs", "1"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve-worker");
    let mut banner = String::new();
    BufReader::new(worker.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-worker banner: {banner:?}"));

    let dir = tmpdir("cli");
    let out = Command::new(bin)
        .args(["train", "--preset", "tiny", "--topics", "8", "--iters", "4"])
        .args(["--runtime", "nomad", "--workers", "1", "--remote", addr])
        .args(["--eval", "rust", "--quiet"])
        .args(["--checkpoint-dir", dir.to_str().unwrap(), "--max-restarts", "2"])
        .output()
        .expect("run train");
    assert!(
        out.status.success(),
        "train failed: {}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("recovered: restarted from epoch"), "no recovery line: {stderr}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("throughput"), "no summary line: {stdout}");
    assert!(!stdout.contains("throughput = 0 tokens/s"), "zero throughput: {stdout}");

    // the worker self-terminated with exit 9 (simulated kill); the ring
    // then ran on without it, so only reap the process — no status check
    let _ = worker.wait();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: after its ring partner is gone, a persistent `serve-worker`
/// returns to listening (named `rebind` line) and serves a second
/// coordinator.
#[test]
fn serve_worker_rebinds_between_runs_via_cli() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    // no --once (rebind is the point), no --quiet (the rebind line is a
    // per-connection log and stays behind the quiet gate)
    let mut worker = Command::new(bin)
        .args(["serve-worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve-worker");
    let mut banner = String::new();
    BufReader::new(worker.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-worker banner: {banner:?}"))
        .to_string();

    for seed in ["1", "2"] {
        let out = Command::new(bin)
            .args(["train", "--preset", "tiny", "--topics", "8", "--iters", "2"])
            .args(["--runtime", "nomad", "--workers", "1", "--remote", &addr])
            .args(["--eval", "rust", "--quiet", "--seed", seed])
            .output()
            .expect("run train");
        assert!(
            out.status.success(),
            "train (seed {seed}) failed: {}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }

    worker.kill().expect("kill serve-worker");
    let mut stderr = String::new();
    worker.stderr.take().unwrap().read_to_string(&mut stderr).unwrap();
    let _ = worker.wait();
    assert!(stderr.contains("rebind"), "no rebind line between sessions: {stderr}");
}
