//! Cross-process nomad over loopback TCP: a mixed local/remote ring must
//! satisfy the same epoch protocol, exact-fold invariant, and gathered
//! state consistency as the all-threads ring, and ring failures (a
//! dropped peer, a rejected handshake) must be descriptive errors, not
//! hangs.

use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::thread;

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::state::Hyper;
use fnomad_lda::nomad::net::{read_frame, serve, write_frame, ServeOpts};
use fnomad_lda::nomad::wire::{Frame, Init};
use fnomad_lda::nomad::{NomadConfig, NomadRuntime};

/// Bind a loopback `serve-worker` on a free port, serving one session on
/// a background thread.
fn spawn_loopback_worker() -> (String, thread::JoinHandle<Result<(), String>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = thread::spawn(move || {
        serve(listener, &ServeOpts { once: true, quiet: true, ..Default::default() })
    });
    (addr, handle)
}

/// The acceptance scenario: 1 local thread + 1 remote loopback worker run
/// ≥3 epochs on `tiny`; the gathered state passes the same consistency
/// checks as the threaded run of identical seed and ring size, and both
/// keep the exact totals `Σ s == num_tokens`.
#[test]
fn loopback_mixed_ring_matches_threaded_consistency() {
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(8);

    let (addr, server) = spawn_loopback_worker();
    let cfg = NomadConfig { workers: 1, seed: 11, remote: vec![addr] };
    let mut mixed = NomadRuntime::new(&corpus, hyper, cfg);
    assert_eq!(mixed.ring_size(), 2);
    for _ in 0..3 {
        let report = mixed.run_epoch();
        // every occurrence lives in exactly one slot's partition → the
        // exact-fold invariant holds across the process boundary
        assert_eq!(report.processed as usize, corpus.num_tokens());
    }
    let state = mixed.gather_state(&corpus);
    state.check_consistency(&corpus).unwrap();
    assert_eq!(state.total_tokens() as usize, corpus.num_tokens());
    mixed.shutdown();
    server.join().unwrap().unwrap();

    // all-threads reference ring: same seed, same slot count
    let cfg = NomadConfig { workers: 2, seed: 11, ..Default::default() };
    let mut threaded = NomadRuntime::new(&corpus, hyper, cfg);
    for _ in 0..3 {
        threaded.run_epoch();
    }
    let reference = threaded.gather_state(&corpus);
    reference.check_consistency(&corpus).unwrap();
    assert_eq!(reference.total_tokens(), state.total_tokens());
    threaded.shutdown();
}

/// A fully remote ring (0 local threads) works too: the coordinator only
/// relays, every token is resampled out of process.
#[test]
fn fully_remote_ring_trains() {
    let corpus = preset("tiny").unwrap();
    let (addr, server) = spawn_loopback_worker();
    let cfg = NomadConfig { workers: 0, seed: 3, remote: vec![addr] };
    let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), cfg);
    for _ in 0..2 {
        let report = rt.run_epoch();
        assert_eq!(report.processed as usize, corpus.num_tokens());
    }
    let state = rt.gather_state(&corpus);
    state.check_consistency(&corpus).unwrap();
    rt.shutdown();
    server.join().unwrap().unwrap();
}

/// Two real processes through the actual CLI: `serve-worker` hosts the
/// remote slot, `train --remote` drives the ring, and the run reports
/// nonzero throughput.
#[test]
fn two_process_loopback_via_cli() {
    let bin = env!("CARGO_BIN_EXE_fnomad-lda");
    let mut worker = Command::new(bin)
        .args(["serve-worker", "--listen", "127.0.0.1:0", "--once", "--quiet"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn serve-worker");
    let mut banner = String::new();
    BufReader::new(worker.stdout.take().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner
        .trim()
        .strip_prefix("listening on ")
        .unwrap_or_else(|| panic!("unexpected serve-worker banner: {banner:?}"));

    let out = Command::new(bin)
        .args(["train", "--preset", "tiny", "--topics", "8", "--iters", "3"])
        .args(["--runtime", "nomad", "--workers", "1", "--remote", addr])
        .args(["--eval", "rust", "--quiet"])
        .output()
        .expect("run train");
    assert!(
        out.status.success(),
        "train failed: {}\n{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("throughput"), "no summary line: {stdout}");
    assert!(!stdout.contains("throughput = 0 tokens/s"), "zero throughput: {stdout}");

    let status = worker.wait().expect("serve-worker exit");
    assert!(status.success(), "serve-worker failed: {status}");
}

/// A TCP peer that vanishes mid-epoch must surface as a descriptive
/// error from `try_run_epoch`, not a coordinator deadlock.
#[test]
fn dropped_tcp_peer_is_an_error_not_a_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake_peer = thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        match read_frame(&mut reader).unwrap() {
            Frame::Init(_) => {}
            other => panic!("expected Init, got {other:?}"),
        }
        write_frame(&mut writer, &Frame::InitOk).unwrap();
        // accept one ring message, then vanish mid-epoch
        let _ = read_frame(&mut reader);
    });

    let corpus = preset("tiny").unwrap();
    let cfg = NomadConfig { workers: 1, seed: 2, remote: vec![addr.clone()] };
    let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), cfg);
    let err = rt.try_run_epoch().unwrap_err();
    assert!(err.contains(&addr), "error must name the peer: {err}");
    assert!(
        err.contains("disconnected") || err.contains("send failed"),
        "error must describe the drop: {err}"
    );
    fake_peer.join().unwrap();
    rt.shutdown();
}

/// `serve-worker` answers a malformed handshake with a descriptive `Err`
/// frame instead of dying silently.
#[test]
fn serve_worker_rejects_bad_handshakes() {
    // a frame that is not Init
    let (addr, server) = spawn_loopback_worker();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    write_frame(&mut writer, &Frame::InitOk).unwrap();
    match read_frame(&mut reader).unwrap() {
        Frame::Err(e) => assert!(e.contains("Init"), "unhelpful rejection: {e}"),
        other => panic!("expected Err frame, got {other:?}"),
    }
    // a failed --once session is the server's error too (exit code)
    server.join().unwrap().unwrap_err();

    // an Init whose payload is inconsistent (z shorter than the slice)
    let (addr, server) = spawn_loopback_worker();
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let bad = Init {
        worker_id: 1,
        num_workers: 2,
        start_doc: 0,
        t: 8,
        alpha: 50.0 / 8.0,
        beta: 0.01,
        vocab: 4,
        doc_offsets: vec![0, 3],
        tokens: vec![0, 1, 2],
        z: vec![0],
        s: vec![1; 8],
        rng_state: 1,
        rng_inc: 3,
    };
    write_frame(&mut writer, &Frame::Init(Box::new(bad))).unwrap();
    match read_frame(&mut reader).unwrap() {
        Frame::Err(e) => {
            assert!(e.contains("invalid Init"), "unhelpful rejection: {e}");
        }
        other => panic!("expected Err frame, got {other:?}"),
    }
    server.join().unwrap().unwrap_err();
}
